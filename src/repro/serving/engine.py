"""Vectorized serving engine — the hot-path replacement for ``sim.py``.

``VectorizedServingEngine`` runs the same §5.1 serving methodology as
:class:`repro.serving.sim.ServingSimulator` but replaces the per-request /
per-replica Python object loops with NumPy array state:

* the request tape is compiled once into ``float64`` arrays (arrival
  times, roofline service times, client-region codes) — no
  ``LatencyModel`` call or ``Request`` attribute chase ever happens inside
  the sub-tick loop;
* arrivals are delivered in batches with ``np.searchsorted`` over the
  arrival array;
* timeout expiry over deep pending/queue backlogs is a vectorized mask
  over the arrival array instead of a per-entry Python scan;
* per-replica RTTs are precomputed per client-region code at replica
  creation, so the load balancer's ``(load, rtt, id)`` key needs no
  string parsing through ``region_rtt_ms``;
* completions are tracked in a global min-heap of finish times: a sub-tick
  only visits replicas that have a finish due or received new work, and
  sub-ticks where provably nothing can happen are skipped outright;
* dead replicas cost nothing — the legacy simulator probes every replica
  it ever created on every sub-tick, which degrades linearly with
  preemption churn over long volatile traces.

The engine is **decision-for-decision equivalent** to the legacy
simulator: it visits the same sub-tick grid points (same float
accumulation), delivers the same arrival batches to the autoscaler,
assigns requests to replicas with the same ``(load, rtt, id)`` /
round-robin rules, applies the same interference factor at dispatch, and
fails the same requests at the same instants.  ``tests/test_differential.py``
locks the equivalence down; ``tests/test_golden.py`` pins the metrics.

``replica_model="token"`` switches the request path to the
continuous-batching model (``repro.serving.token``): each replica slot
carries a :class:`ContinuousBatch` whose per-sequence state lives in
NumPy arrays, dispatch enqueues tape indices into batches instead of
pushing precomputed finish times, and a per-sub-tick batched step loop
advances every busy batch (closed-form decode blocks, so cost scales
with joins/leaves, not decode iterations).  Token mode is
decision-for-decision equivalent to the legacy simulator's
``TokenReplica`` path (``tests/test_token_engine.py``).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.cluster.catalog import Catalog, default_catalog, region_rtt_ms
from repro.cluster.instance import Instance, InstanceState
from repro.migration.config import MigrationSpec
from repro.migration.runtime import MigrationRuntime
from repro.cluster.simulator import ClusterSimulator, SimConfig
from repro.cluster.traces import SpotTrace
from repro.core.autoscaler import Autoscaler, ConstantTarget
from repro.core.policy import Policy
from repro.models.config import ModelConfig
from repro.obs.recorder import ObsRecorder
from repro.obs.registry import use_registry
from repro.serving.latency import LatencyModel
from repro.serving.load_balancer import (
    LeastLoadedBalancer,
    LoadBalancer,
    RoundRobinBalancer,
)
from repro.serving.sim import REPLICA_MODELS, ServingResult, WindowSampler
from repro.serving.token.batch import ContinuousBatch
from repro.serving.token.config import (
    TokenEngineConfig,
    TokenSchedulerConfig,
)
from repro.serving.token.metrics import TokenRecord, TokenStats
from repro.workloads.arrivals import Request

__all__ = ["VectorizedServingEngine"]

_INF = float("inf")
# below this size a plain Python scan beats numpy call overhead
_VEC_MIN = 24


class _Rep:
    """Array-era replica record: plain slots, no FSM object, no probes."""

    __slots__ = ("inst", "slot", "rid", "dead", "rtt",
                 "running", "queue", "qage", "qmin", "batch", "ord")

    def __init__(self, inst: Instance, slot: int,
                 rtt: List[float]) -> None:
        self.inst = inst
        self.slot = slot
        self.rid = inst.id
        self.ord = -1                        # dense obs ordinal (spans)
        self.dead = False
        self.rtt = rtt                       # client-region code -> seconds
        self.running: List[Tuple[float, int]] = []   # (finish_s, req index)
        self.queue: List[int] = []                   # req indices, FIFO
        # parallel *effective* ages: arrival − client RTT, so the shared
        # `t - age > timeout` expiry predicate is RTT-inclusive
        # (t - (arr - rtt) > to  ⇔  t - arr + rtt > to), matching the
        # deadline applied to completed responses
        self.qage: List[float] = []
        self.qmin = _INF                     # lower bound on queued eff. ages
        self.batch: Optional[ContinuousBatch] = None   # token mode only

    @property
    def load(self) -> int:
        if self.batch is not None:
            return self.batch.load
        return len(self.running) + len(self.queue)


class VectorizedServingEngine:
    """Drop-in for :class:`ServingSimulator` with an array-based hot path."""

    def __init__(
        self,
        trace: SpotTrace,
        policy: Policy,
        requests: Sequence[Request],
        cfg: ModelConfig,
        *,
        itype: str = "p3.2xlarge",
        catalog: Optional[Catalog] = None,
        autoscaler: Optional[Autoscaler] = None,
        lb: Optional[LoadBalancer] = None,
        sim_config: Optional[SimConfig] = None,
        timeout_s: float = 100.0,
        sub_step_s: float = 1.0,
        workload_name: str = "workload",
        concurrency: Optional[int] = None,
        concurrency_cap: int = 16,
        latency_model: Optional[LatencyModel] = None,
        replica_model: str = "request",
        token_scheduler: Optional[TokenSchedulerConfig] = None,
        migration: Optional[MigrationSpec] = None,
        obs: Optional[ObsRecorder] = None,
    ) -> None:
        # shared event recorder (repro.obs): the cluster, migration
        # runtime and window sampler all emit into this one sink, so the
        # stream is byte-identical to the legacy simulator's
        self.obs = obs if obs is not None else ObsRecorder()
        self.catalog = catalog or default_catalog()
        self.cfg = cfg
        self.itype = self.catalog.instance_type(itype)
        # an injected model (e.g. ProfiledLatencyModel from the spec's
        # latency: section) replaces the default analytic roofline
        self.latency_model = (
            latency_model
            if latency_model is not None
            else LatencyModel.for_model(cfg, self.itype)
        )
        self.timeout_s = timeout_s
        self.sub_step_s = sub_step_s
        self.workload_name = workload_name
        self.concurrency = concurrency or min(
            self.latency_model.max_concurrency(), concurrency_cap
        )
        if replica_model not in REPLICA_MODELS:
            raise ValueError(
                f"replica_model must be one of {list(REPLICA_MODELS)}, "
                f"got {replica_model!r}"
            )
        self.replica_model = replica_model
        self._token_knobs = token_scheduler or TokenSchedulerConfig()
        self._token_cfg: Optional[TokenEngineConfig] = (
            TokenEngineConfig.from_latency(
                self.latency_model, self._token_knobs
            )
            if replica_model == "token" else None
        )
        # the SLO-burn monitor inside the sampler needs the token-mode
        # latency targets, so construction waits for the knobs above
        self._win = WindowSampler(
            self.obs,
            slo_ttft_s=(
                self._token_knobs.slo_ttft_s
                if self._token_cfg is not None else None
            ),
            slo_tpot_s=(
                self._token_knobs.slo_tpot_s
                if self._token_cfg is not None else None
            ),
        )
        self._token_records: List[TokenRecord] = []
        self._busy: Set[int] = set()         # slots with live batch work
        self._n_kv_preempted = 0
        self._n_killed_queued = 0
        self._lost_prefill_tokens = 0
        self._lost_decode_tokens = 0
        self._n_retried = 0
        if migration is not None and migration.enabled \
                and self._token_cfg is None:
            raise ValueError(
                "migration.enabled requires replica_model='token'"
            )
        self._mig_rt: Optional[MigrationRuntime] = (
            MigrationRuntime(migration, self._token_cfg, obs=self.obs)
            if migration is not None and migration.enabled
            and self._token_cfg is not None else None
        )
        self._n_drained = 0
        self._n_migrated = 0
        self._migrated_kv_tokens = 0
        self._saved_prefill_tokens = 0
        self._saved_decode_tokens = 0
        self._migration_transfer_s = 0.0
        self._recompute_saved_s = 0.0

        lb = lb or LeastLoadedBalancer()
        # exact types only: a subclass may override pick(), and silently
        # simulating it as the vanilla balancer would be wrong
        if type(lb) is RoundRobinBalancer:
            self._lb_kind = "rr"
        elif type(lb) is LeastLoadedBalancer:
            self._lb_kind = "ll"
        else:
            raise TypeError(
                f"VectorizedServingEngine supports LeastLoadedBalancer and "
                f"RoundRobinBalancer, got {type(lb).__name__}; use the "
                "legacy ServingSimulator (sim.engine: legacy) for custom "
                "balancers"
            )
        self._rr_cursor = 0

        # ---- compile the request tape into arrays ---------------------
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        self.requests = reqs
        # request-span collector (None when off / unsampled).  The tape
        # is the stable arrival-sort, so tape index == span ordinal and
        # the hot loops test want_l[i] directly — no id lookup.
        self._spans = self.obs.span_collector(reqs)
        n = len(reqs)
        self._n = n
        self._arr = np.fromiter(
            (r.arrival_s for r in reqs), dtype=np.float64, count=n
        )
        p_tok = np.fromiter(
            (r.prompt_tokens for r in reqs), dtype=np.float64, count=n
        )
        o_tok = np.fromiter(
            (r.output_tokens for r in reqs), dtype=np.float64, count=n
        )
        lm = self.latency_model
        # same operation order as LatencyModel.service_s so every value is
        # bit-identical to the legacy per-request computation
        prefill = (2.0 * lm._active_params) * p_tok / lm.flops_per_s
        self._svc = (lm.overhead_s + prefill) + o_tok * lm.decode_s_per_token()
        # Python-list mirrors for scalar access: list indexing and float
        # arithmetic are several times faster than numpy scalar indexing
        # in the per-request loops, and .tolist() round-trips exactly
        self._arr_l: List[float] = self._arr.tolist()
        self._svc_l: List[float] = self._svc.tolist()
        if self._token_cfg is not None:
            # token mode prices work in tokens, not frozen service times
            self._ptok_l: List[int] = [int(v) for v in p_tok]
            self._otok_l: List[int] = [int(v) for v in o_tok]

        # client regions as small int codes; each replica precomputes its
        # RTT per code on creation
        regions: List[str] = []
        region_code: Dict[str, int] = {}
        rcode = np.empty(n, dtype=np.int32)
        for i, r in enumerate(reqs):
            c = region_code.get(r.client_region)
            if c is None:
                c = region_code[r.client_region] = len(regions)
                regions.append(r.client_region)
            rcode[i] = c
        self._rcode = rcode
        self._rcode_l: List[int] = rcode.tolist()
        self._client_regions = regions

        # ---- mutable serving state ------------------------------------
        self._ptr = 0                        # next arrival index
        self._pending: List[int] = []        # request indices, FIFO
        self._pmin = _INF                    # min arrival over pending
        self._qn = 0                         # total queued entries
        self._qmin = _INF                    # min arrival over queued
        self._heap: List[Tuple[float, int]] = []   # (finish_s, slot)
        self._reps: List[_Rep] = []          # insertion order (mirrors dict)
        self._live: List[_Rep] = []          # non-dead, insertion order
        self._live_dirty = False
        self._by_id: Dict[int, _Rep] = {}
        self._obs: List[Tuple[float, int]] = []   # autoscaler batch
        self._touched: Set[int] = set()      # slots enqueued at this point
        self._due: Set[int] = set()          # slots with finishes due
        # per-control-window LB state (ready set is constant in a window)
        self._ready_slots: List[int] = []
        self._ready_reps: List[_Rep] = []
        self._pos: Dict[int, int] = {}       # slot -> index in ready lists
        self._loads: List[int] = []
        self._ids: List[int] = []
        self._cols: Dict[int, List[float]] = {}   # rcode -> rtt column

        self.latencies: List[float] = []
        self.failed = 0
        self.completed = 0

        if sim_config is None:
            cfg_sim = SimConfig(itype=itype, control_interval_s=15.0)
        else:
            cfg_sim = dataclasses.replace(sim_config, itype=itype)
        self.cluster = ClusterSimulator(
            trace,
            policy,
            catalog=self.catalog,
            autoscaler=autoscaler or ConstantTarget(4),
            config=cfg_sim,
            tick_hook=self._tick,
            obs=self.obs,
        )
        self.cluster.add_preempt_listener(self._on_dead)
        self.cluster.add_terminate_listener(self._on_dead)
        self._observe_batch = self.cluster.autoscaler.observe_batch
        self._searchsorted = self._arr.searchsorted

    # ------------------------------------------------------------------
    # replica lifecycle
    # ------------------------------------------------------------------
    def _new_rep(self, inst: Instance) -> _Rep:
        rtt = [
            region_rtt_ms(creg, inst.region) / 1e3
            for creg in self._client_regions
        ]
        rep = _Rep(inst, len(self._reps), rtt)
        if self._spans is not None:
            rep.ord = self.obs.replica_ordinal(inst.id)
        if self._token_cfg is not None:
            rep.batch = ContinuousBatch(self._token_cfg, tap=self._spans)
        self._reps.append(rep)
        self._live.append(rep)
        self._by_id[inst.id] = rep
        return rep

    def _kill(self, rep: _Rep, now: Optional[float] = None) -> None:
        """Preemption/termination: in-flight then queued back to pending."""
        if rep.dead:
            return
        if rep.batch is not None:
            # token mode: the whole batch loses its KV state; every
            # request (in-flight and queued) retries client-side —
            # unless migration is on and the preemption was warned
            rep.dead = True
            self._live_dirty = True
            inst = rep.inst
            if (
                self._mig_rt is not None
                and now is not None
                and inst.state is InstanceState.PREEMPTED
                and inst.warned_at is not None
            ):
                kr = self._kill_with_migration(rep, now)
            else:
                kr = rep.batch.kill()
            arr = self._arr_l
            pending = self._pending
            pmin = self._pmin
            spans = self._spans
            want = spans.want_l if spans is not None else None
            t_kill = now if now is not None else 0.0
            for i in kr.keys:
                pending.append(i)
                if arr[i] < pmin:
                    pmin = arr[i]
                if want is not None and want[i]:
                    spans.preempt(i, t_kill)
            self._pmin = pmin
            self._n_retried += len(kr.keys)
            self._busy.discard(rep.slot)
            self._n_kv_preempted += kr.n_batch
            self._n_killed_queued += kr.n_queued
            self._lost_prefill_tokens += kr.lost_prefill_tokens
            self._lost_decode_tokens += kr.lost_decode_tokens
            return
        rep.dead = True
        self._live_dirty = True
        arr = self._arr_l
        pending = self._pending
        pmin = self._pmin
        spans = self._spans
        want = spans.want_l if spans is not None else None
        t_kill = now if now is not None else 0.0
        for _, i in rep.running:
            pending.append(i)
            if arr[i] < pmin:
                pmin = arr[i]
            if want is not None and want[i]:
                spans.preempt(i, t_kill)
        for i in rep.queue:
            pending.append(i)
            if arr[i] < pmin:
                pmin = arr[i]
            if want is not None and want[i]:
                spans.preempt(i, t_kill)
        self._pmin = pmin
        self._n_retried += len(rep.running) + len(rep.queue)
        self._qn -= len(rep.queue)
        rep.running = []
        rep.queue = []
        rep.qage = []
        rep.qmin = _INF

    def _kill_with_migration(self, rep: _Rep, now: float):
        """Warned preemption with migration on: drain/migrate/kill the
        dying batch (decision-identical to the legacy simulator's path).
        Returns the residual KillReport."""
        inst = rep.inst
        grace = now - inst.warned_at
        cands = sorted(
            (
                r for r in self._live
                if r is not rep and not r.dead
                and r.batch is not None and r.inst.is_ready()
            ),
            key=lambda r: r.rid,
        )
        outcome = self._mig_rt.execute_preemption(
            rep.batch, inst,
            [(r.rid, r.batch, r.inst) for r in cands],
            now, grace,
        )
        cfg = self._token_cfg
        finish = now + cfg.overhead_s
        rcode = self._rcode_l
        arr = self._arr_l
        records = self._token_records
        spans = self._spans
        want = spans.want_l if spans is not None else None
        for s in outcome.drained:
            # finished decoding inside the grace window: completes at
            # the kill instant, first token (if any) already emitted
            i = s.key
            rtt = rep.rtt[rcode[i]]
            e2e = finish - arr[i] + rtt
            outcome_ok = e2e <= self.timeout_s
            first = (
                s.first_s + cfg.overhead_s
                if math.isfinite(s.first_s) else finish
            )
            if not outcome_ok:
                self.failed += 1
            else:
                self.latencies.append(e2e)
                self.completed += 1
                records.append(TokenRecord(
                    req_id=i,
                    arrival_s=arr[i],
                    first_token_s=first,
                    finish_s=finish,
                    output_tokens=s.output_tokens,
                    rtt_s=rtt,
                ))
            if want is not None and want[i]:
                spans.finish_token(
                    i, first, finish, cfg.overhead_s,
                    "ok" if outcome_ok else "timeout", e2e,
                )
        by_rid = {r.rid: r for r in cands}
        for m in outcome.migrated:
            # the target batch has queued work now; make sure it steps
            self._busy.add(by_rid[m.target_rid].slot)
        self._n_drained += outcome.n_drained
        self._n_migrated += outcome.n_migrated
        self._migrated_kv_tokens += outcome.migrated_kv_tokens
        self._saved_prefill_tokens += outcome.saved_prefill_tokens
        self._saved_decode_tokens += outcome.saved_decode_tokens
        self._migration_transfer_s += outcome.transfer_s_total
        self._recompute_saved_s += outcome.recompute_saved_s
        return outcome.kill_report

    def _on_dead(self, inst: Instance, now: float) -> None:
        rep = self._by_id.get(inst.id)
        if rep is not None:
            self._kill(rep, now)

    def _sync(self, now: Optional[float] = None) -> None:
        """Reconcile the replica set with the cluster's active instances.

        Instance state only changes at control ticks, so (unlike the legacy
        per-sub-tick probe loop) one reconciliation per window is exact.
        The window-constant LB state (ready order, loads, rtt columns) is
        rebuilt here.
        """
        for inst in self.cluster.instances:
            rep = self._by_id.get(inst.id)
            if rep is None:
                if inst.is_active():
                    self._new_rep(inst)
            elif not inst.is_active():
                self._kill(rep, now)
        if self._live_dirty:
            self._live = [r for r in self._live if not r.dead]
            self._live_dirty = False
        ready = [r for r in self._live if r.inst.is_ready()]
        self._ready_reps = ready
        self._ready_slots = [r.slot for r in ready]
        self._pos = {r.slot: j for j, r in enumerate(ready)}
        self._loads = [r.load for r in ready]
        self._ids = [r.rid for r in ready]
        self._cols = {}

    # ------------------------------------------------------------------
    # sub-tick loop
    # ------------------------------------------------------------------
    def _active(self, t: float) -> bool:
        """Could anything at all happen at grid point ``t``?

        Conservative: a false positive costs one no-op pass (exactly what
        the legacy simulator does on every sub-tick), never correctness.
        """
        if self._ptr < self._n and self._arr_l[self._ptr] <= t:
            return True
        if self._heap and self._heap[0][0] <= t:
            return True
        if self._pending:
            if self._ready_slots:
                return True
            if t - self._pmin > self.timeout_s:
                return True
        if self._qn and t - self._qmin > self.timeout_s:
            return True
        return False

    def _tick(self, now: float, cluster: ClusterSimulator) -> None:
        self._sync(now)
        dt = cluster.config.control_interval_s
        t = now
        end = now + dt
        token = self._token_cfg is not None
        # identical float accumulation to the legacy loop so grid points,
        # arrival batches and timeout instants match bit-for-bit
        while t < end:
            if token:
                if self._active_token(t):
                    self._process_token(t)
            elif self._active(t):
                self._process(t, cluster)
            t += self.sub_step_s
        # flush arrival observations before the cluster reads target():
        # batch-equivalent to per-sub-tick observe() calls (eviction is
        # idempotent), amortizing the call overhead per control window
        if self._obs:
            self._observe_batch(self._obs)
            self._obs.clear()
        self._win.maybe_emit(
            now,
            delivered=self._ptr,
            completed=self.completed,
            failed=self.failed,
            instances=cluster.instances,
            token_records=(
                self._token_records if self._token_cfg is not None
                else None
            ),
        )

    def _process(self, t: float, cluster: ClusterSimulator) -> None:
        # 1) arrivals
        ptr = self._ptr
        if ptr < self._n and self._arr_l[ptr] <= t:
            new_ptr = int(self._searchsorted(t, side="right"))
            self._pending.extend(range(ptr, new_ptr))
            m = self._arr_l[ptr]
            if m < self._pmin:
                self._pmin = m
            self._ptr = new_ptr
            self._obs.append((t, new_ptr - ptr))
        # 2) slots with completions due, from the finish-time heap.  Found
        #    BEFORE dispatch so the dispatch fast path knows which replicas
        #    may not start work until their completions are processed.
        due = self._due
        due.clear()
        heap = self._heap
        reps = self._reps
        while heap and heap[0][0] <= t:
            _, s = heapq.heappop(heap)
            if not reps[s].dead:
                due.add(s)
        # 3) dispatch (fills self._touched with slots that got new queued
        #    work; replicas with free capacity, an empty queue and no due
        #    completion start the request immediately — identical to the
        #    legacy queue-then-start within the same sub-tick, because the
        #    dispatch timeout filter already applied the expiry predicate)
        touched = self._touched
        touched.clear()
        if self._pending:
            self._dispatch(t, due)
        # 4) step the affected replicas.  Untouched slots cannot change:
        #    their running set shrinks only via a due finish (a heap pop)
        #    and their queue only drains into slots freed the same way —
        #    except queue expiry, which is wall-clock driven and handled
        #    by the guarded full pass (per-replica qmin bounds make it a
        #    skip for replicas that cannot hold an expired entry).
        if self._qn and self.timeout_s > 0 \
                and t - self._qmin > self.timeout_s:
            self._step(t, self._ready_slots, due, expire=True)
            qmin_g = _INF
            for r in self._ready_reps:
                if r.qmin < qmin_g:
                    qmin_g = r.qmin
            self._qmin = qmin_g
        elif due:
            slots = sorted(due | touched) if touched else sorted(due)
            self._step(t, slots, due, expire=False)
        elif touched:
            self._step(t, sorted(touched), due, expire=False)
        if self._qn == 0:
            self._qmin = _INF

    # ------------------------------------------------------------------
    def _dispatch(self, t: float, due: Set[int]) -> None:
        pending = self._pending
        arr = self._arr_l
        timeout = self.timeout_s
        ready = self._ready_slots
        spans = self._spans
        want = spans.want_l if spans is not None else None
        if not ready:
            # nothing to route to; age out requests past their timeout
            if len(pending) >= _VEC_MIN:
                arr_v = self._arr
                pa = np.fromiter(pending, dtype=np.int64,
                                 count=len(pending))
                keep = (t - arr_v[pa]) <= timeout
                n_keep = int(keep.sum())
                if n_keep != len(pending):
                    self.failed += len(pending) - n_keep
                    if want is not None:
                        for i in pa[~keep].tolist():
                            if want[i]:
                                spans.expire(i, t, arr[i])
                    pa = pa[keep]
                    self._pending = pa.tolist()
                    self._pmin = (
                        float(arr_v[pa].min()) if n_keep else _INF
                    )
            else:
                kept: List[int] = []
                pmin = _INF
                for i in pending:
                    if t - arr[i] > timeout:
                        self.failed += 1
                        if want is not None and want[i]:
                            spans.expire(i, t, arr[i])
                    else:
                        kept.append(i)
                        if arr[i] < pmin:
                            pmin = arr[i]
                self._pending = kept
                self._pmin = pmin
            return

        reps = self._reps
        touched = self._touched
        svc = self._svc_l
        rcode = self._rcode_l
        heap = self._heap
        conc = self.concurrency
        qn = 0
        qmin = self._qmin
        # pmin is a lower bound on every pending arrival, so when even the
        # oldest request is within the timeout the per-request check is skipped
        check_to = t - self._pmin > timeout
        if self._lb_kind == "rr":
            nready = len(ready)
            loads = self._loads
            cur = self._rr_cursor
            for i in pending:
                if check_to and t - arr[i] > timeout:
                    self.failed += 1
                    if want is not None and want[i]:
                        spans.expire(i, t, arr[i])
                    continue
                j = cur % nready
                s = ready[j]
                cur += 1
                # RR routing ignores loads, but _step's completion/expiry
                # bookkeeping decrements them, so keep the counts honest
                loads[j] += 1
                rep = reps[s]
                if want is not None and want[i]:
                    spans.dispatch(
                        i, t, rep.ord, rep.rtt[rcode[i]], arr[i]
                    )
                run = rep.running
                if not rep.queue and len(run) < conc and s not in due:
                    # immediate start == queue-then-start this sub-tick
                    finish = t + svc[i] * (1.0 + 0.15 * len(run))
                    run.append((finish, i))
                    heapq.heappush(heap, (finish, s))
                    if want is not None and want[i]:
                        spans.start(i, t)
                    continue
                a = arr[i] - rep.rtt[rcode[i]]
                rep.queue.append(i)
                rep.qage.append(a)
                touched.add(s)
                qn += 1
                if a < qmin:
                    qmin = a
                if a < rep.qmin:
                    rep.qmin = a
            self._rr_cursor = cur
        else:
            # least-loaded waterfill: sequentially assign each request to
            # argmin (load, rtt, id) — the pick the legacy LB's min() makes
            ready_reps = self._ready_reps
            loads = self._loads
            ids = self._ids
            cols = self._cols
            rcode = self._rcode_l
            nready = len(ready)
            rng = range(1, nready)
            for i in pending:
                if check_to and t - arr[i] > timeout:
                    self.failed += 1
                    if want is not None and want[i]:
                        spans.expire(i, t, arr[i])
                    continue
                rc = rcode[i]
                col = cols.get(rc)
                if col is None:
                    col = cols[rc] = [r.rtt[rc] for r in ready_reps]
                best, bl, br, bi = 0, loads[0], col[0], ids[0]
                for j in rng:
                    lj = loads[j]
                    if lj > bl:
                        continue
                    if lj < bl or col[j] < br or (
                        col[j] == br and ids[j] < bi
                    ):
                        best, bl, br, bi = j, lj, col[j], ids[j]
                loads[best] += 1
                rep = ready_reps[best]
                if want is not None and want[i]:
                    spans.dispatch(i, t, rep.ord, col[best], arr[i])
                run = rep.running
                if not rep.queue and len(run) < conc \
                        and rep.slot not in due:
                    finish = t + svc[i] * (1.0 + 0.15 * len(run))
                    run.append((finish, i))
                    heapq.heappush(heap, (finish, rep.slot))
                    if want is not None and want[i]:
                        spans.start(i, t)
                    continue
                a = arr[i] - rep.rtt[rc]
                rep.queue.append(i)
                rep.qage.append(a)
                touched.add(rep.slot)
                qn += 1
                if a < qmin:
                    qmin = a
                if a < rep.qmin:
                    rep.qmin = a
        self._qn += qn
        self._qmin = qmin
        # with ready replicas, every non-expired request was routed
        self._pending = []
        self._pmin = _INF

    # ------------------------------------------------------------------
    def _step(self, t: float, slots: Sequence[int], due: Set[int],
              expire: bool) -> None:
        arr = self._arr_l
        svc = self._svc_l
        rcode = self._rcode_l
        timeout = self.timeout_s
        conc = self.concurrency
        heap = self._heap
        reps = self._reps
        loads = self._loads
        pos = self._pos
        spans = self._spans
        want = spans.want_l if spans is not None else None
        for s in slots:
            rep = reps[s]
            run = rep.running
            # completions (in start order, like the legacy running list)
            if s in due:
                still: List[Tuple[float, int]] = []
                n_done = 0
                for f, i in run:
                    if f <= t:
                        e2e = (f - arr[i]) + rep.rtt[rcode[i]]
                        ok = e2e <= timeout
                        if not ok:
                            self.failed += 1
                        else:
                            self.latencies.append(e2e)
                            self.completed += 1
                        if want is not None and want[i]:
                            spans.finish(
                                i, f, "ok" if ok else "timeout", e2e
                            )
                        n_done += 1
                    else:
                        still.append((f, i))
                rep.running = run = still
                loads[pos[s]] -= n_done
            # queue expiry (client hung up past its timeout).  Expired
            # entries are almost always a FIFO prefix, so pop from the
            # front; the post-pop min detects the rare mid-queue stragglers
            # (retried requests carry their original arrival time).
            q = rep.queue
            if expire and q and t - rep.qmin > timeout:
                ages = rep.qage
                nq = len(q)
                k = 0
                while k < nq and t - ages[k] > timeout:
                    k += 1
                if k:
                    if want is not None:
                        for i in q[:k]:
                            if want[i]:
                                spans.expire(i, t, arr[i])
                    del q[:k]
                    del ages[:k]
                    self.failed += k
                    self._qn -= k
                    loads[pos[s]] -= k
                if ages:
                    qmin = min(ages)
                    if t - qmin > timeout:
                        kept: List[int] = []
                        kept_a: List[float] = []
                        n_exp = 0
                        for i, a in zip(q, ages):
                            if t - a > timeout:
                                n_exp += 1
                                if want is not None and want[i]:
                                    spans.expire(i, t, arr[i])
                            else:
                                kept.append(i)
                                kept_a.append(a)
                        rep.queue = q = kept
                        rep.qage = ages = kept_a
                        self.failed += n_exp
                        self._qn -= n_exp
                        loads[pos[s]] -= n_exp
                        qmin = min(ages) if ages else _INF
                    rep.qmin = qmin
                else:
                    rep.qmin = _INF
            # starts: pull queued work into free slots
            if q and len(run) < conc:
                j = 0
                nq = len(q)
                while j < nq and len(run) < conc:
                    i = q[j]
                    j += 1
                    finish = t + svc[i] * (1.0 + 0.15 * len(run))
                    run.append((finish, i))
                    heapq.heappush(heap, (finish, s))
                    if want is not None and want[i]:
                        spans.start(i, t)
                del q[:j]
                del rep.qage[:j]
                self._qn -= j

    # ------------------------------------------------------------------
    # token mode: continuous-batching hot path
    # ------------------------------------------------------------------
    def _active_token(self, t: float) -> bool:
        """Token-mode activity check: arrivals due, routable/expirable
        pending work, or any replica with live batch state."""
        if self._ptr < self._n and self._arr_l[self._ptr] <= t:
            return True
        if self._pending:
            if self._ready_slots:
                return True
            if t - self._pmin > self.timeout_s:
                return True
        if self._busy:
            return True
        return False

    def _process_token(self, t: float) -> None:
        # 1) arrivals (identical batching to the request-mode path)
        ptr = self._ptr
        if ptr < self._n and self._arr_l[ptr] <= t:
            new_ptr = int(self._searchsorted(t, side="right"))
            self._pending.extend(range(ptr, new_ptr))
            m = self._arr_l[ptr]
            if m < self._pmin:
                self._pmin = m
            self._ptr = new_ptr
            self._obs.append((t, new_ptr - ptr))
        # 2) route pending into replica batches
        if self._pending:
            self._dispatch_token(t)
        # 3) run every busy batch's iterations up to t
        if self._busy:
            self._advance_batches(t)

    def _dispatch_token(self, t: float) -> None:
        pending = self._pending
        arr = self._arr_l
        timeout = self.timeout_s
        ready = self._ready_slots
        spans = self._spans
        want = spans.want_l if spans is not None else None
        if not ready:
            # nothing to route to; age out requests past their timeout
            kept: List[int] = []
            pmin = _INF
            for i in pending:
                if t - arr[i] > timeout:
                    self.failed += 1
                    if want is not None and want[i]:
                        spans.expire(i, t, arr[i])
                else:
                    kept.append(i)
                    if arr[i] < pmin:
                        pmin = arr[i]
            self._pending = kept
            self._pmin = pmin
            return
        reps = self._reps
        busy = self._busy
        ptok = self._ptok_l
        otok = self._otok_l
        rcode = self._rcode_l
        check_to = t - self._pmin > timeout
        if self._lb_kind == "rr":
            nready = len(ready)
            loads = self._loads
            cur = self._rr_cursor
            for i in pending:
                if check_to and t - arr[i] > timeout:
                    self.failed += 1
                    if want is not None and want[i]:
                        spans.expire(i, t, arr[i])
                    continue
                j = cur % nready
                s = ready[j]
                cur += 1
                rep = reps[s]
                ok = rep.batch.enqueue(i, ptok[i], otok[i], arr[i], t,
                                       rtt_s=rep.rtt[rcode[i]])
                if ok:
                    loads[j] += 1
                    busy.add(s)
                else:
                    self.failed += 1     # can never fit the KV budget
                if want is not None and want[i]:
                    # same tap order as TokenReplica.submit: dispatch,
                    # then track (admitted) or reject (unservable)
                    spans.dispatch(
                        i, t, rep.ord, rep.rtt[rcode[i]], arr[i],
                        token=True,
                    )
                    if ok:
                        rep.batch.track(i, i)
                    else:
                        spans.reject(i, t)
            self._rr_cursor = cur
        else:
            # least-loaded waterfill over (load, rtt, id), load = batch
            # occupancy + admission queue — same pick as the legacy LB
            ready_reps = self._ready_reps
            loads = self._loads
            ids = self._ids
            cols = self._cols
            rcode = self._rcode_l
            nready = len(ready)
            rng = range(1, nready)
            for i in pending:
                if check_to and t - arr[i] > timeout:
                    self.failed += 1
                    if want is not None and want[i]:
                        spans.expire(i, t, arr[i])
                    continue
                rc = rcode[i]
                col = cols.get(rc)
                if col is None:
                    col = cols[rc] = [r.rtt[rc] for r in ready_reps]
                best, bl, br, bi = 0, loads[0], col[0], ids[0]
                for j in rng:
                    lj = loads[j]
                    if lj > bl:
                        continue
                    if lj < bl or col[j] < br or (
                        col[j] == br and ids[j] < bi
                    ):
                        best, bl, br, bi = j, lj, col[j], ids[j]
                rep = ready_reps[best]
                ok = rep.batch.enqueue(i, ptok[i], otok[i], arr[i], t,
                                       rtt_s=rep.rtt[rc])
                if ok:
                    loads[best] += 1
                    busy.add(rep.slot)
                else:
                    self.failed += 1
                if want is not None and want[i]:
                    spans.dispatch(
                        i, t, rep.ord, rep.rtt[rc], arr[i], token=True
                    )
                    if ok:
                        rep.batch.track(i, i)
                    else:
                        spans.reject(i, t)
        self._pending = []
        self._pmin = _INF

    def _advance_batches(self, t: float) -> None:
        timeout = self.timeout_s
        loads = self._loads
        pos = self._pos
        rcode = self._rcode_l
        records = self._token_records
        spans = self._spans
        want = spans.want_l if spans is not None else None
        overhead = self._token_cfg.overhead_s
        idle: List[int] = []
        for s in sorted(self._busy):
            rep = self._reps[s]
            batch = rep.batch
            n_removed = 0
            for c in batch.advance(t):
                i = c.key
                rtt = rep.rtt[rcode[i]]
                e2e = c.finish_s - c.arrival_s + rtt
                ok = e2e <= timeout
                if not ok:
                    self.failed += 1
                else:
                    self.latencies.append(e2e)
                    self.completed += 1
                    records.append(TokenRecord(
                        req_id=i,
                        arrival_s=c.arrival_s,
                        first_token_s=c.first_token_s,
                        finish_s=c.finish_s,
                        output_tokens=c.output_tokens,
                        rtt_s=rtt,
                    ))
                if want is not None and want[i]:
                    spans.finish_token(
                        i, c.first_token_s, c.finish_s, overhead,
                        "ok" if ok else "timeout", e2e,
                    )
                n_removed += 1
            if timeout > 0 and batch.n_queued:
                expired = batch.expire_queue(t, timeout)
                self.failed += len(expired)
                if want is not None:
                    arr = self._arr_l
                    for i in expired:
                        if want[i]:
                            spans.expire(i, t, arr[i])
                n_removed += len(expired)
            if n_removed:
                loads[pos[s]] -= n_removed
            if batch.load == 0:
                idle.append(s)
        for s in idle:
            self._busy.discard(s)

    # ------------------------------------------------------------------
    def run(self, duration_s: Optional[float] = None) -> ServingResult:
        # run-scope the metrics registry so library-level counters
        # (e.g. latency-model fallbacks) land on this run, not a global
        with use_registry(self.obs.registry):
            base = self.cluster.run(duration_s)
        # drain: anything still pending/in-flight past the horizon fails
        self.failed += len(self._pending)
        for rep in self._reps:
            self.failed += rep.load
        if self._spans is not None:
            self._spans.finalize(base.duration_s)
        token_stats = None
        if self._token_cfg is not None:
            knobs = self._token_knobs
            token_stats = TokenStats.from_records(
                self._token_records,
                slo_ttft_s=knobs.slo_ttft_s,
                slo_tpot_s=knobs.slo_tpot_s,
                horizon_s=base.duration_s,
                window_s=knobs.goodput_window_s,
                n_requests=self._ptr,
                n_kv_preempted_seqs=self._n_kv_preempted,
                n_killed_queued=self._n_killed_queued,
                lost_prefill_tokens=self._lost_prefill_tokens,
                lost_decode_tokens=self._lost_decode_tokens,
                n_drained_seqs=self._n_drained,
                n_migrated_seqs=self._n_migrated,
                migrated_kv_tokens=self._migrated_kv_tokens,
                saved_prefill_tokens=self._saved_prefill_tokens,
                saved_decode_tokens=self._saved_decode_tokens,
                migration_transfer_s=self._migration_transfer_s,
                recompute_saved_s=self._recompute_saved_s,
            )
        return ServingResult(
            policy=self.cluster.policy.name,
            trace=self.cluster.trace.name,
            workload=self.workload_name,
            n_requests=self._ptr,
            n_completed=self.completed,
            n_failed=self.failed,
            latencies_s=np.asarray(self.latencies),
            total_cost=base.total_cost,
            spot_cost=base.spot_cost,
            od_cost=base.od_cost,
            cost_vs_ondemand=base.cost_vs_ondemand,
            availability=base.availability,
            n_preemptions=base.n_preemptions,
            n_launch_failures=base.n_launch_failures,
            token=token_stats,
            n_retried_requests=self._n_retried,
            lost_kv_tokens=(
                self._lost_prefill_tokens + self._lost_decode_tokens
            ),
            metrics=self.obs.registry.snapshot() or None,
            obs=self.obs if self.obs.enabled else None,
        )

"""Serving-quality simulator — the §5.1 methodology, replayable.

Composes the cluster simulator (policy × spot trace × instances) with the
request path (workload → LB → replica queues → latency model).  Produces
the paper's headline metrics: P50/P90/P99 end-to-end latency, failure
rate (timeouts from preemption + queueing), cost, and ready-replica
series (Fig. 9/10/13/15).

Mechanics:

* requests arrive continuously; the LB routes to ready replicas only,
* a preemption kills a replica; its in-flight requests are retried by the
  client — the wasted time counts into that request's e2e latency,
* a request that cannot complete within ``timeout_s`` of its arrival is a
  failure (the paper's definition),
* replica service times come from the roofline latency model; queueing is
  M/G/c per replica with sub-tick stepping for accurate waits,
* ``replica_model="token"`` swaps the M/G/c replicas for the
  continuous-batching model in ``repro.serving.token`` (KV-budget
  admission, chunked prefill, batch-dependent decode steps) and attaches
  TTFT/TPOT/goodput ``TokenStats`` to the result.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.catalog import Catalog, default_catalog
from repro.cluster.instance import Instance, InstanceKind, InstanceState
from repro.migration.config import MigrationSpec
from repro.migration.runtime import MigrationRuntime
from repro.cluster.simulator import ClusterSimulator, SimConfig
from repro.cluster.traces import SpotTrace
from repro.core.autoscaler import Autoscaler, ConstantTarget
from repro.core.policy import Policy
from repro.models.config import ModelConfig
from repro.obs.events import WindowSampleEvent
from repro.obs.recorder import ObsRecorder
from repro.obs.registry import use_registry
from repro.obs.slo import SLOBurnMonitor
from repro.serving.latency import LatencyModel
from repro.serving.load_balancer import LeastLoadedBalancer, LoadBalancer
from repro.serving.replica import Replica, ReplicaState
from repro.serving.token.config import (
    TokenEngineConfig,
    TokenSchedulerConfig,
)
from repro.serving.token.metrics import TokenRecord, TokenStats
from repro.serving.token.replica import TokenReplica
from repro.workloads.arrivals import Request

REPLICA_MODELS = ("request", "token")


class WindowSampler:
    """Windowed data-plane sampling (observability detail ``full``).

    Both serving engines drive this one code path with order-independent
    inputs (cumulative counters + instantaneous cluster state at the
    control-tick boundary), which is what makes their window samples —
    and therefore their whole event JSONL — byte-identical.  The SLO
    burn-rate monitor hangs off the same choke point: every sample
    window also folds its error counts into the trailing fast/slow burn
    windows and emits one :class:`~repro.obs.events.SLOBurnEvent`.
    """

    def __init__(
        self,
        obs: ObsRecorder,
        slo_ttft_s: Optional[float] = None,
        slo_tpot_s: Optional[float] = None,
    ) -> None:
        self.obs = obs
        self._next_t = 0.0
        self._last_t = 0.0
        self._last_completed = 0
        self._last_failed = 0
        self._records_seen = 0
        self._burn = SLOBurnMonitor(
            obs.slo_burn, slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s
        )

    def maybe_emit(
        self,
        now: float,
        *,
        delivered: int,
        completed: int,
        failed: int,
        instances: Sequence[Instance],
        token_records: Optional[Sequence[TokenRecord]] = None,
    ) -> None:
        if not self.obs.wants_windows or now < self._next_t:
            return
        n_ready = n_spot = n_od = 0
        cost_per_h = 0.0
        for inst in instances:
            cost_per_h += inst.hourly_price
            if inst.state is InstanceState.READY:
                n_ready += 1
                if inst.kind is InstanceKind.SPOT:
                    n_spot += 1
                else:
                    n_od += 1
        elapsed = now - self._last_t
        delta = completed - self._last_completed
        goodput = delta / elapsed if elapsed > 0 else 0.0
        ttft_p50: Optional[float] = None
        new: Optional[Sequence[TokenRecord]] = None
        if token_records is not None:
            new = token_records[self._records_seen:]
            self._records_seen = len(token_records)
            if new:
                # median over the window's completion multiset: order-
                # independent, so engine-internal completion order
                # differences cannot leak into the sample
                ttft_p50 = float(np.median(sorted(
                    r.ttft_s for r in new
                )))
        self.obs.emit_window(WindowSampleEvent(
            t=now,
            queue_depth=delivered - completed - failed,
            n_ready=n_ready,
            n_spot=n_spot,
            n_od=n_od,
            cost_per_h=cost_per_h,
            n_completed=completed,
            n_failed=failed,
            goodput_rps=goodput,
            ttft_p50_s=ttft_p50,
        ))
        # burn rates from the same order-independent window deltas
        self.obs.emit_window(self._burn.observe(
            now,
            d_completed=delta,
            d_failed=failed - self._last_failed,
            new_records=new,
        ))
        self._last_t = now
        self._last_completed = completed
        self._last_failed = failed
        self._next_t = now + self.obs.window_s


@dataclasses.dataclass
class ServingResult:
    policy: str
    trace: str
    workload: str
    n_requests: int
    n_completed: int
    n_failed: int
    latencies_s: np.ndarray
    total_cost: float
    spot_cost: float
    od_cost: float
    cost_vs_ondemand: float
    availability: float
    n_preemptions: int = 0
    n_launch_failures: int = 0
    # token-level metrics (replica_model="token" runs only)
    token: Optional[TokenStats] = None
    # uniform kill accounting across replica models (both engines):
    # requests pushed back to the client for retry after a replica died,
    # and KV tokens destroyed doing so (always 0 in request mode)
    n_retried_requests: int = 0
    lost_kv_tokens: int = 0
    # observability (repro.obs): the run's metrics-registry snapshot and
    # the recorder holding the typed event stream (None when detail=off)
    metrics: Optional[Dict[str, Any]] = None
    obs: Optional[ObsRecorder] = None

    @property
    def failure_rate(self) -> float:
        return self.n_failed / max(self.n_requests, 1)

    def pct(self, q: float) -> float:
        if len(self.latencies_s) == 0:
            return float("nan")
        return float(np.percentile(self.latencies_s, q))

    def summary(self) -> str:
        out = (
            f"{self.policy:>16s} @ {self.trace}/{self.workload} "
            f"p50={self.pct(50):6.2f}s p90={self.pct(90):6.2f}s "
            f"p99={self.pct(99):7.2f}s fail={self.failure_rate:6.2%} "
            f"cost={self.cost_vs_ondemand:6.2%} avail={self.availability:.2%}"
        )
        if self.token is not None:
            out += (
                f" ttft_p50={self.token.ttft_pct(50):5.2f}s "
                f"goodput={self.token.goodput_rps:.3f}req/s "
                f"slo={self.token.slo_attainment:.2%}"
            )
        return out


class ServingSimulator:
    def __init__(
        self,
        trace: SpotTrace,
        policy: Policy,
        requests: Sequence[Request],
        cfg: ModelConfig,
        *,
        itype: str = "p3.2xlarge",
        catalog: Optional[Catalog] = None,
        autoscaler: Optional[Autoscaler] = None,
        lb: Optional[LoadBalancer] = None,
        sim_config: Optional[SimConfig] = None,
        timeout_s: float = 100.0,
        sub_step_s: float = 1.0,
        workload_name: str = "workload",
        concurrency: Optional[int] = None,
        concurrency_cap: int = 16,
        latency_model: Optional[LatencyModel] = None,
        replica_model: str = "request",
        token_scheduler: Optional[TokenSchedulerConfig] = None,
        migration: Optional[MigrationSpec] = None,
        obs: Optional[ObsRecorder] = None,
    ) -> None:
        self.catalog = catalog or default_catalog()
        self.obs = obs if obs is not None else ObsRecorder()
        self.cfg = cfg
        self.itype = self.catalog.instance_type(itype)
        # an injected model (e.g. ProfiledLatencyModel from the spec's
        # latency: section) replaces the default analytic roofline
        self.latency_model = (
            latency_model
            if latency_model is not None
            else LatencyModel.for_model(cfg, self.itype)
        )
        self.lb = lb or LeastLoadedBalancer()
        self.timeout_s = timeout_s
        self.sub_step_s = sub_step_s
        self.workload_name = workload_name
        self.concurrency = concurrency
        self.concurrency_cap = concurrency_cap
        if replica_model not in REPLICA_MODELS:
            raise ValueError(
                f"replica_model must be one of {list(REPLICA_MODELS)}, "
                f"got {replica_model!r}"
            )
        self.replica_model = replica_model
        self._token_knobs = token_scheduler or TokenSchedulerConfig()
        self._token_cfg: Optional[TokenEngineConfig] = (
            TokenEngineConfig.from_latency(
                self.latency_model, self._token_knobs
            )
            if replica_model == "token" else None
        )
        # the sampler needs the token SLO targets for burn rates, so it
        # is built after the token knobs are resolved
        self._win = WindowSampler(
            self.obs,
            slo_ttft_s=(
                self._token_knobs.slo_ttft_s
                if self._token_cfg is not None else None
            ),
            slo_tpot_s=(
                self._token_knobs.slo_tpot_s
                if self._token_cfg is not None else None
            ),
        )
        self._token_records: List[TokenRecord] = []
        self._n_kv_preempted = 0
        self._n_killed_queued = 0
        self._lost_prefill_tokens = 0
        self._lost_decode_tokens = 0
        self._n_retried = 0
        if migration is not None and migration.enabled \
                and self._token_cfg is None:
            raise ValueError(
                "migration.enabled requires replica_model='token'"
            )
        self._mig_rt: Optional[MigrationRuntime] = (
            MigrationRuntime(migration, self._token_cfg, obs=self.obs)
            if migration is not None and migration.enabled
            and self._token_cfg is not None else None
        )
        self._n_drained = 0
        self._n_migrated = 0
        self._migrated_kv_tokens = 0
        self._saved_prefill_tokens = 0
        self._saved_decode_tokens = 0
        self._migration_transfer_s = 0.0
        self._recompute_saved_s = 0.0

        self.requests = sorted(requests, key=lambda r: r.arrival_s)
        # request-span collector (None when off / unsampled): taps below
        # fire only for sampled ordinals, keyed via want_ids[req.id]
        self._spans = self.obs.span_collector(self.requests)
        self._next_arrival = 0
        self.pending: List[Request] = []       # waiting for a replica
        self._deadline: Dict[int, float] = {}  # req id -> timeout time
        self._arrival: Dict[int, float] = {}
        self.latencies: List[float] = []
        self.failed = 0
        self.completed = 0

        self.replicas: Dict[int, Replica] = {}

        if sim_config is None:
            cfg_sim = SimConfig(itype=itype, control_interval_s=15.0)
        else:
            # never mutate the caller's (possibly shared) SimConfig
            cfg_sim = dataclasses.replace(sim_config, itype=itype)
        self.cluster = ClusterSimulator(
            trace,
            policy,
            catalog=self.catalog,
            autoscaler=autoscaler or ConstantTarget(4),
            config=cfg_sim,
            tick_hook=self._tick,
            obs=self.obs,
        )
        self.cluster.add_preempt_listener(self._on_dead)
        # scale-downs retire instances from the cluster's scan list, so the
        # replica layer must hear about them here (not via _sync_replicas)
        self.cluster.add_terminate_listener(self._on_dead)

    # ------------------------------------------------------------------
    def _new_replica(self, inst: Instance) -> Replica:
        tap = self._spans
        ord_ = (
            self.obs.replica_ordinal(inst.id) if tap is not None else -1
        )
        if self._token_cfg is not None:
            return TokenReplica(
                inst, self.latency_model, self._token_cfg,
                timeout_s=self.timeout_s,
                span_tap=tap, span_ord=ord_,
            )
        return Replica(
            inst, self.latency_model,
            concurrency=self.concurrency,
            concurrency_cap=self.concurrency_cap,
            timeout_s=self.timeout_s,
            span_tap=tap, span_ord=ord_,
        )

    def _sync_replicas(self, now: float) -> None:
        for inst in self.cluster.instances:
            if inst.id not in self.replicas and inst.is_active():
                self.replicas[inst.id] = self._new_replica(inst)
            elif inst.id in self.replicas and not inst.is_active():
                self._kill_replica(inst.id, now)
        for r in self.replicas.values():
            r.readiness_probe(now)

    def _kill_replica(self, rid: int, now: float) -> None:
        rep = self.replicas.get(rid)
        if rep is None or rep.state is ReplicaState.DEAD:
            return
        if (
            self._mig_rt is not None
            and isinstance(rep, TokenReplica)
            and rep.instance.state is InstanceState.PREEMPTED
            and rep.instance.warned_at is not None
        ):
            self._kill_with_migration(rep, now)
            return
        killed = rep.kill()
        self._n_retried += len(killed)
        tap = self._spans
        for req in killed:
            # client retry: back into the pending pool
            self.pending.append(req)
            if tap is not None:
                o = tap.want_ids.get(req.id)
                if o is not None:
                    tap.preempt(o, now)
        if isinstance(rep, TokenReplica) and rep.kill_report is not None:
            kr = rep.kill_report
            self._n_kv_preempted += kr.n_batch
            self._n_killed_queued += kr.n_queued
            self._lost_prefill_tokens += kr.lost_prefill_tokens
            self._lost_decode_tokens += kr.lost_decode_tokens

    def _kill_with_migration(self, rep: TokenReplica, now: float) -> None:
        """Warned preemption with migration on: drain/migrate/kill the
        dying batch instead of re-prefilling everything elsewhere."""
        inst = rep.instance
        grace = now - inst.warned_at
        targets = sorted(
            (
                rp for rp in self.replicas.values()
                if rp is not rep
                and isinstance(rp, TokenReplica)
                and rp.state is not ReplicaState.DEAD
                and rp.instance.is_ready()
            ),
            key=lambda rp: rp.instance.id,
        )
        outcome, drained, failed = rep.kill_migrating(
            self._mig_rt, targets, now, grace
        )
        cfg = self._token_cfg
        finish = now + cfg.overhead_s
        tap = self._spans
        for req, s in drained:
            # finished decoding inside the grace window: completes at
            # the kill instant, first token (if any) already emitted
            rtt = LoadBalancer.rtt_s(req, rep)
            e2e = finish - self._arrival[req.id] + rtt
            outcome_ok = e2e <= self.timeout_s
            if not outcome_ok:
                self.failed += 1
            else:
                self.latencies.append(e2e)
                self.completed += 1
            first = (
                s.first_s + cfg.overhead_s
                if math.isfinite(s.first_s) else finish
            )
            if outcome_ok:
                self._token_records.append(TokenRecord(
                    req_id=req.id,
                    arrival_s=self._arrival[req.id],
                    first_token_s=first,
                    finish_s=finish,
                    output_tokens=s.output_tokens,
                    rtt_s=rtt,
                ))
            if tap is not None:
                o = tap.want_ids.get(req.id)
                if o is not None:
                    tap.finish_token(
                        o, first, finish, cfg.overhead_s,
                        "ok" if outcome_ok else "timeout", e2e,
                    )
        self._n_retried += len(failed)
        for req in failed:
            self.pending.append(req)
            if tap is not None:
                o = tap.want_ids.get(req.id)
                if o is not None:
                    tap.preempt(o, now)
        kr = outcome.kill_report
        self._n_kv_preempted += kr.n_batch
        self._n_killed_queued += kr.n_queued
        self._lost_prefill_tokens += kr.lost_prefill_tokens
        self._lost_decode_tokens += kr.lost_decode_tokens
        self._n_drained += outcome.n_drained
        self._n_migrated += outcome.n_migrated
        self._migrated_kv_tokens += outcome.migrated_kv_tokens
        self._saved_prefill_tokens += outcome.saved_prefill_tokens
        self._saved_decode_tokens += outcome.saved_decode_tokens
        self._migration_transfer_s += outcome.transfer_s_total
        self._recompute_saved_s += outcome.recompute_saved_s

    def _on_dead(self, inst: Instance, now: float) -> None:
        self._kill_replica(inst.id, now)

    # ------------------------------------------------------------------
    def _dispatch(self, now: float) -> None:
        ready = [
            r for r in self.replicas.values()
            if r.state is ReplicaState.READY
        ]
        self.lb.update_ready(ready)
        tap = self._spans
        token = self._token_cfg is not None
        still: List[Request] = []
        for req in self.pending:
            if now - self._arrival[req.id] > self.timeout_s:
                self.failed += 1
                if tap is not None:
                    o = tap.want_ids.get(req.id)
                    if o is not None:
                        tap.expire(o, now, req.arrival_s)
                continue
            rep = self.lb.route(req, now)
            if rep is None:
                still.append(req)
            elif tap is not None and not token:
                # token mode taps inside TokenReplica.submit (it knows
                # the admission outcome); request mode taps here
                o = tap.want_ids.get(req.id)
                if o is not None:
                    tap.dispatch(
                        o, now, rep.span_ord,
                        LoadBalancer.rtt_s(req, rep), req.arrival_s,
                    )
        self.pending = still

    def _step_replicas(self, now: float) -> None:
        token = self._token_cfg is not None
        tap = self._spans
        for rep in self.replicas.values():
            if rep.state is not ReplicaState.READY:
                continue
            done, expired = rep.step(now)
            self.failed += len(expired)
            if tap is not None:
                for req in expired:
                    o = tap.want_ids.get(req.id)
                    if o is not None:
                        # rejected admissions in `expired` already carry
                        # their outcome; expire() is a no-op for them
                        tap.expire(o, now, req.arrival_s)
            comps = rep.take_completions() if token else None
            for k, (req, finish) in enumerate(done):
                rtt = LoadBalancer.rtt_s(req, rep)
                e2e = finish - self._arrival[req.id] + rtt
                ok = e2e <= self.timeout_s
                if not ok:
                    self.failed += 1
                else:
                    self.latencies.append(e2e)
                    self.completed += 1
                    if comps is not None:
                        c = comps[k]
                        self._token_records.append(TokenRecord(
                            req_id=req.id,
                            arrival_s=self._arrival[req.id],
                            first_token_s=c.first_token_s,
                            finish_s=c.finish_s,
                            output_tokens=c.output_tokens,
                            rtt_s=rtt,
                        ))
                if tap is not None:
                    o = tap.want_ids.get(req.id)
                    if o is not None:
                        outcome = "ok" if ok else "timeout"
                        if comps is not None:
                            c = comps[k]
                            tap.finish_token(
                                o, c.first_token_s, c.finish_s,
                                self._token_cfg.overhead_s,
                                outcome, e2e,
                            )
                        else:
                            tap.finish(o, finish, outcome, e2e)

    def _tick(self, now: float, cluster: ClusterSimulator) -> None:
        dt = cluster.config.control_interval_s
        t = now
        end = now + dt
        while t < end:
            self._sync_replicas(t)
            # deliver arrivals up to t
            n_new = 0
            while (
                self._next_arrival < len(self.requests)
                and self.requests[self._next_arrival].arrival_s <= t
            ):
                req = self.requests[self._next_arrival]
                self._arrival[req.id] = req.arrival_s
                self.pending.append(req)
                self._next_arrival += 1
                n_new += 1
            if n_new:
                cluster.autoscaler.observe(t, n_new)
            self._dispatch(t)
            self._step_replicas(t)
            t += self.sub_step_s
        self._win.maybe_emit(
            now,
            delivered=self._next_arrival,
            completed=self.completed,
            failed=self.failed,
            instances=cluster.instances,
            token_records=(
                self._token_records if self._token_cfg is not None
                else None
            ),
        )

    # ------------------------------------------------------------------
    def run(self, duration_s: Optional[float] = None) -> ServingResult:
        # run-scope the metrics registry so library-level counters
        # (e.g. latency-model fallbacks) land on this run, not a global
        with use_registry(self.obs.registry):
            base = self.cluster.run(duration_s)
        # drain: anything still pending/in-flight past the horizon fails
        self.failed += len(self.pending)
        for rep in self.replicas.values():
            self.failed += rep.load
        if self._spans is not None:
            self._spans.finalize(base.duration_s)
        n_total = self._next_arrival
        token_stats = None
        if self._token_cfg is not None:
            knobs = self._token_knobs
            token_stats = TokenStats.from_records(
                self._token_records,
                slo_ttft_s=knobs.slo_ttft_s,
                slo_tpot_s=knobs.slo_tpot_s,
                horizon_s=base.duration_s,
                window_s=knobs.goodput_window_s,
                n_requests=n_total,
                n_kv_preempted_seqs=self._n_kv_preempted,
                n_killed_queued=self._n_killed_queued,
                lost_prefill_tokens=self._lost_prefill_tokens,
                lost_decode_tokens=self._lost_decode_tokens,
                n_drained_seqs=self._n_drained,
                n_migrated_seqs=self._n_migrated,
                migrated_kv_tokens=self._migrated_kv_tokens,
                saved_prefill_tokens=self._saved_prefill_tokens,
                saved_decode_tokens=self._saved_decode_tokens,
                migration_transfer_s=self._migration_transfer_s,
                recompute_saved_s=self._recompute_saved_s,
            )
        return ServingResult(
            policy=self.cluster.policy.name,
            trace=self.cluster.trace.name,
            workload=self.workload_name,
            n_requests=n_total,
            n_completed=self.completed,
            n_failed=self.failed,
            latencies_s=np.asarray(self.latencies),
            total_cost=base.total_cost,
            spot_cost=base.spot_cost,
            od_cost=base.od_cost,
            cost_vs_ondemand=base.cost_vs_ondemand,
            availability=base.availability,
            n_preemptions=base.n_preemptions,
            n_launch_failures=base.n_launch_failures,
            token=token_stats,
            n_retried_requests=self._n_retried,
            lost_kv_tokens=(
                self._lost_prefill_tokens + self._lost_decode_tokens
            ),
            metrics=self.obs.registry.snapshot() or None,
            obs=self.obs if self.obs.enabled else None,
        )

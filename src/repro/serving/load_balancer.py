"""Load balancers (§4): round-robin and least-outstanding-requests, with
cross-region RTT accounting and client-side retry on replica death.

The balancer only routes to replicas whose readiness probe has passed (the
controller forwards the ready set each tick).  Requests carry the client
region; the RTT to the serving replica's region is added to the measured
end-to-end latency (Fig. 6b model) — the paper's argument is that this
term is small against LLM processing time.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.cluster.catalog import Catalog, region_rtt_ms
from repro.serving.replica import Replica, ReplicaState
from repro.workloads.arrivals import Request


class LoadBalancer:
    name = "lb"

    def __init__(self) -> None:
        self._ready: List[Replica] = []

    def update_ready(self, replicas: Sequence[Replica]) -> None:
        self._ready = [
            r for r in replicas if r.state is ReplicaState.READY
        ]

    def pick(self, req: Request, now: float) -> Optional[Replica]:
        raise NotImplementedError

    def route(self, req: Request, now: float) -> Optional[Replica]:
        r = self.pick(req, now)
        if r is not None:
            r.submit(req, now)
        return r

    @staticmethod
    def rtt_s(req: Request, replica: Replica) -> float:
        return region_rtt_ms(req.client_region, replica.region) / 1e3


class RoundRobinBalancer(LoadBalancer):
    name = "round_robin"

    def __init__(self) -> None:
        super().__init__()
        self._cursor = 0

    def pick(self, req: Request, now: float) -> Optional[Replica]:
        if not self._ready:
            return None
        r = self._ready[self._cursor % len(self._ready)]
        self._cursor += 1
        return r


class LeastLoadedBalancer(LoadBalancer):
    """Route to the replica with the fewest outstanding requests; ties go
    to the lower-RTT region (the §6 'advanced policy' extension)."""

    name = "least_loaded"

    def pick(self, req: Request, now: float) -> Optional[Replica]:
        if not self._ready:
            return None
        return min(
            self._ready,
            key=lambda r: (r.load, self.rtt_s(req, r), r.id),
        )

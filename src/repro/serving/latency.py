"""Roofline-derived request latency model per (model config × instance).

The paper's Fig. 6a decomposes a Vicuna-13B request: model execution time
(prefill + per-token decode) dominates; network RTT is tens of ms.  We
reproduce that structure analytically so the serving simulator's service
times are grounded in the same hardware model as the §Roofline analysis:

    prefill_s(P)      = 2·N·P FLOPs / (accels × peak_flops × MFU_prefill)
    decode_s_per_tok  = weight bytes / (accels × HBM_bw) / MBU_decode
    service_s(req)    = prefill + out_tokens × decode + overhead

Prefill is compute-bound (MFU ~0.45 on a tuned engine); decode is
HBM-bound (weights re-read per token; MBU ~0.7).  The same model yields a
replica's max concurrency from its HBM capacity (KV per request).
"""

from __future__ import annotations

import dataclasses

from repro.cluster.catalog import InstanceType
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    cfg: ModelConfig
    itype: InstanceType
    n_params: float
    mfu_prefill: float = 0.45
    mbu_decode: float = 0.70
    overhead_s: float = 0.05        # tokenize/detokenize/HTTP

    @classmethod
    def for_model(cls, cfg: ModelConfig, itype: InstanceType,
                  n_params: float = 0.0) -> "LatencyModel":
        n = n_params or float(cfg.approx_params())
        return cls(cfg=cfg, itype=itype, n_params=n)

    # ------------------------------------------------------------------
    @property
    def _active_params(self) -> float:
        cfg = self.cfg
        if not cfg.is_moe:
            return self.n_params
        expert = (
            cfg.num_layers * cfg.num_experts
            * (3 if cfg.mlp_gated else 2) * cfg.d_model * cfg.expert_d_ff
        )
        return self.n_params - expert * (
            1.0 - cfg.experts_per_token / cfg.num_experts
        )

    @property
    def flops_per_s(self) -> float:
        return (
            self.itype.accel_count
            * self.itype.peak_bf16_tflops * 1e12
            * self.mfu_prefill
        )

    @property
    def hbm_bytes_per_s(self) -> float:
        # scale HBM bw with the accelerator class (A100 2 TB/s, V100
        # 0.9 TB/s, T4 0.3 TB/s, A10G 0.6 TB/s, v5e 0.819 TB/s)
        bw = {
            "A100": 2.0e12, "V100": 0.9e12, "T4": 0.3e12,
            "A10G": 0.6e12, "K80": 0.24e12, "TPUv5e": 0.819e12,
        }.get(self.itype.accelerator, 0.8e12)
        return self.itype.accel_count * bw * self.mbu_decode

    # ------------------------------------------------------------------
    def prefill_s(self, prompt_tokens: int) -> float:
        return 2.0 * self._active_params * prompt_tokens / self.flops_per_s

    def decode_s_per_token(self) -> float:
        weight_bytes = 2.0 * self._active_params     # bf16
        return weight_bytes / self.hbm_bytes_per_s

    def service_s(self, prompt_tokens: int, output_tokens: int) -> float:
        return (
            self.overhead_s
            + self.prefill_s(prompt_tokens)
            + output_tokens * self.decode_s_per_token()
        )

    # ------------------------------------------------------------------
    def max_concurrency(self, max_ctx: int = 4096) -> int:
        """Requests servable concurrently from leftover HBM (KV budget).
        Attention-free archs are compute-limited instead (use 32)."""
        cfg = self.cfg
        hbm = (
            self.itype.accel_count * self.itype.hbm_gib_per_accel * 2**30
        )
        weights = 2.0 * self.n_params
        free = max(hbm * 0.9 - weights, hbm * 0.05)
        if cfg.num_kv_heads and cfg.resolved_head_dim:
            slots = (
                min(max_ctx, cfg.sliding_window or max_ctx)
            )
            kv_per_req = (
                2 * cfg.num_layers * slots * cfg.num_kv_heads
                * cfg.resolved_head_dim * 2
            )
            return max(1, int(free / kv_per_req))
        return 32

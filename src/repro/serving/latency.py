"""Request latency models per (model config × instance): roofline + profiled.

The paper's Fig. 6a decomposes a Vicuna-13B request: model execution time
(prefill + per-token decode) dominates; network RTT is tens of ms.  We
reproduce that structure analytically so the serving simulator's service
times are grounded in the same hardware model as the §Roofline analysis:

    prefill_s(P)      = 2·N·P FLOPs / (accels × peak_flops × MFU_prefill)
    decode_s_per_tok  = weight bytes / (accels × HBM_bw) / MBU_decode
    service_s(req)    = prefill + out_tokens × decode + overhead

Prefill is compute-bound; decode is HBM-bound (weights re-read per
token).  :class:`LatencyModel` uses literature-typical efficiency
constants (MFU ~0.45 on a tuned engine, MBU ~0.7);
:class:`ProfiledLatencyModel` replaces those constants with efficiencies
*measured* on this repo's Pallas kernels by ``repro.profiles`` — same
roofline structure, measured numerator.  ``make_latency_model`` picks
between them from a ``ServiceSpec``'s ``latency:`` section, falling back
to the analytic roofline when no profile entry matches, so default runs
(and the golden metrics) are byte-identical with or without profile
artifacts on disk.

Peak HBM bandwidth lives on :class:`repro.cluster.catalog.InstanceType`
(resolved from ``ACCEL_HBM_BYTES_PER_S`` by accelerator name — unknown
accelerators raise at catalog construction instead of silently serving
from a guessed 0.8 TB/s part).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, TYPE_CHECKING

from repro.cluster.catalog import InstanceType
from repro.models.config import ModelConfig

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from repro.profiles.schema import ProfileEntry

__all__ = [
    "LatencyModel",
    "ProfiledLatencyModel",
    "make_latency_model",
]


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    cfg: ModelConfig
    itype: InstanceType
    n_params: float
    mfu_prefill: float = 0.45
    mbu_decode: float = 0.70
    overhead_s: float = 0.05        # tokenize/detokenize/HTTP

    @classmethod
    def for_model(cls, cfg: ModelConfig, itype: InstanceType,
                  n_params: float = 0.0) -> "LatencyModel":
        n = n_params or float(cfg.approx_params())
        return cls(cfg=cfg, itype=itype, n_params=n)

    # ------------------------------------------------------------------
    @property
    def _active_params(self) -> float:
        cfg = self.cfg
        if not cfg.is_moe:
            return self.n_params
        expert = (
            cfg.num_layers * cfg.num_experts
            * (3 if cfg.mlp_gated else 2) * cfg.d_model * cfg.expert_d_ff
        )
        return self.n_params - expert * (
            1.0 - cfg.experts_per_token / cfg.num_experts
        )

    @property
    def flops_per_s(self) -> float:
        return (
            self.itype.accel_count
            * self.itype.peak_bf16_tflops * 1e12
            * self.mfu_prefill
        )

    @property
    def hbm_bytes_per_s(self) -> float:
        # peak per-accelerator bandwidth comes from the instance catalog
        # (cluster.catalog.ACCEL_HBM_BYTES_PER_S keyed by accelerator)
        return (
            self.itype.accel_count
            * self.itype.hbm_bytes_per_s
            * self.mbu_decode
        )

    # ------------------------------------------------------------------
    def prefill_s(self, prompt_tokens: int) -> float:
        return 2.0 * self._active_params * prompt_tokens / self.flops_per_s

    def decode_s_per_token(self) -> float:
        weight_bytes = 2.0 * self._active_params     # bf16
        return weight_bytes / self.hbm_bytes_per_s

    def service_s(self, prompt_tokens: int, output_tokens: int) -> float:
        return (
            self.overhead_s
            + self.prefill_s(prompt_tokens)
            + output_tokens * self.decode_s_per_token()
        )

    # ------------------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        """K+V bf16 bytes one cached token occupies (0: no KV cache)."""
        cfg = self.cfg
        if cfg.num_kv_heads and cfg.resolved_head_dim:
            return float(
                2 * cfg.num_layers * cfg.num_kv_heads
                * cfg.resolved_head_dim * 2
            )
        return 0.0

    def free_kv_hbm_bytes(self) -> float:
        """HBM left for KV cache: 90% usable minus bf16 weights,
        floored at 5% (the shared budget arithmetic — also feeds the
        token engine's ``kv_budget_tokens``)."""
        hbm = (
            self.itype.accel_count * self.itype.hbm_gib_per_accel * 2**30
        )
        weights = 2.0 * self.n_params
        return max(hbm * 0.9 - weights, hbm * 0.05)

    def max_concurrency(self, max_ctx: int = 4096) -> int:
        """Requests servable concurrently from leftover HBM (KV budget).
        Attention-free archs are compute-limited instead (use 32)."""
        cfg = self.cfg
        kv_tok = self.kv_bytes_per_token()
        if kv_tok:
            slots = (
                min(max_ctx, cfg.sliding_window or max_ctx)
            )
            return max(
                1, int(self.free_kv_hbm_bytes() / (kv_tok * slots))
            )
        return 32


@dataclasses.dataclass(frozen=True)
class ProfiledLatencyModel(LatencyModel):
    """Roofline latency with kernel-measured MFU/MBU.

    Identical service-time structure to :class:`LatencyModel`; the
    ``mfu_prefill`` / ``mbu_decode`` efficiency fractions come from a
    ``repro.profiles`` step-time table instead of hand-waved constants.
    Provenance rides along so a result can always answer "which profile
    priced this run, measured where, in which mode".
    """

    profile_path: str = ""
    profile_backend: str = ""       # jax backend the measurement ran on
    profile_mode: str = ""          # "interpret" | "compiled"

    @classmethod
    def from_entry(
        cls,
        cfg: ModelConfig,
        itype: InstanceType,
        entry: "ProfileEntry",
        *,
        path: str = "",
        n_params: float = 0.0,
    ) -> "ProfiledLatencyModel":
        n = n_params or float(cfg.approx_params())
        return cls(
            cfg=cfg,
            itype=itype,
            n_params=n,
            mfu_prefill=entry.mfu_prefill,
            mbu_decode=entry.mbu_decode,
            profile_path=path,
            profile_backend=entry.backend,
            profile_mode=entry.mode,
        )


LATENCY_SOURCES = ("roofline", "profile")

def make_latency_model(
    cfg: ModelConfig,
    itype: InstanceType,
    *,
    model_id: str,
    source: str = "roofline",
    profile: Optional[str] = None,
) -> LatencyModel:
    """Build the latency model a ``ServiceSpec``'s ``latency:`` asks for.

    ``source="roofline"`` (the default) is the analytic model —
    bit-identical to the historical behaviour.  ``source="profile"``
    loads the step-time table(s) at ``profile`` (a JSON file or a
    directory of them; defaults to ``artifacts/profiles/``) and looks up
    ``(model_id, itype.accelerator)``; when no table or no matching entry
    exists it *warns and falls back to the roofline* rather than failing
    the run, so specs stay portable across machines with and without
    profile artifacts.
    """
    if source not in LATENCY_SOURCES:
        raise ValueError(
            f"latency source must be one of {list(LATENCY_SOURCES)}, "
            f"got {source!r}"
        )
    if source == "roofline":
        return LatencyModel.for_model(cfg, itype)

    from repro.profiles.schema import DEFAULT_PROFILE_DIR, load_profiles

    path = profile or DEFAULT_PROFILE_DIR
    table = load_profiles(path, missing_ok=True)
    entry = table.lookup(model_id, itype.accelerator)
    if entry is None:
        # run-scoped counter (repro.obs): warnings scroll away, this
        # lands on the calling run's registry — sweeps and tests can
        # assert a run stayed on measured profiles without cross-run
        # bleed from a process-global tally
        from repro.obs.registry import get_registry

        get_registry().inc(
            "latency_profile_fallback",
            model=model_id,
            accelerator=itype.accelerator,
        )
        warnings.warn(
            f"latency source 'profile': no profile entry for "
            f"({model_id!r}, {itype.accelerator!r}) under {path!r}; "
            "falling back to the analytic roofline model",
            stacklevel=2,
        )
        return LatencyModel.for_model(cfg, itype)
    return ProfiledLatencyModel.from_entry(cfg, itype, entry, path=str(path))

"""Forecaster interface: per-zone spot availability and preemption risk.

A :class:`Forecaster` turns the observation stream a placement policy
already receives — preemption / launch-failure / ready events, plus the
per-tick knowledge of which zones currently host live replicas — into
*forward-looking* per-zone scores:

* ``p_available`` — probability the zone has any obtainable spot capacity
  ``horizon_s`` seconds from now;
* ``p_preempt``  — probability a spot instance running in the zone is
  preempted within the next ``horizon_s`` seconds.

Two input channels feed the same state:

* :meth:`Forecaster.observe` — a (possibly partial) row of binary
  availability observations at a timestamp.  The backtest harness feeds
  full trace rows; a live controller feeds whatever it can see.
* :meth:`Forecaster.observe_event` — the controller's structured
  transitions (:class:`repro.core.policy.ControllerEvent`).  Preemptions
  and launch failures are *down* evidence; ready launches are *up*
  evidence.  Warnings are deliberately ignored — SpotHedge already
  consumes them, and they are advisory, not a capacity measurement.

Implementations live in ``repro.forecast.estimators`` and register
themselves with :func:`register_forecaster`, mirroring the policy
registry, so specs and sweeps can name them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

from repro.cluster.traces import infer_region
from repro.core.policy import ControllerEvent, EventKind

__all__ = [
    "ZoneForecast",
    "Forecaster",
    "infer_region",
    "register_forecaster",
    "make_forecaster",
    "registered_forecasters",
]


@dataclasses.dataclass(frozen=True)
class ZoneForecast:
    """One zone's forward-looking scores over a fixed horizon."""

    zone: str
    p_available: float      # P(any spot capacity at now + horizon)
    p_preempt: float        # P(running instance preempted within horizon)

    def __post_init__(self) -> None:
        for field in ("p_available", "p_preempt"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{field} must be a probability, got {v!r} "
                    f"for zone {self.zone!r}"
                )


class Forecaster:
    """Base class.  Subclasses implement ``_predict_zone`` and the state
    updates behind ``observe``."""

    name: str = "forecaster"

    def __init__(self) -> None:
        self._zones: List[str] = []
        self._region_of: Dict[str, str] = {}
        self._dt: float = 60.0

    # -- lifecycle -----------------------------------------------------
    def reset(
        self,
        zones: Sequence[str],
        zone_region: Optional[Mapping[str, str]] = None,
        dt: float = 60.0,
    ) -> None:
        """Start a fresh history over ``zones``.

        ``zone_region`` scopes sibling-correlation features; missing
        entries fall back to :func:`infer_region`.  ``dt`` is the
        expected observation cadence in seconds — estimators express
        their transition statistics per ``dt`` step.
        """
        self._zones = list(zones)
        self._region_of = {
            z: (zone_region or {}).get(z, infer_region(z)) for z in zones
        }
        self._dt = float(dt)

    # -- observation channels ------------------------------------------
    def observe(self, now: float, available: Mapping[str, bool]) -> None:
        """Record a (partial) row of binary availability observations."""
        raise NotImplementedError

    def observe_event(self, event: ControllerEvent) -> None:
        """Fold one controller transition into the availability history."""
        if event.kind in (EventKind.PREEMPTION, EventKind.LAUNCH_FAILURE):
            self.observe(event.now, {event.zone: False})
        elif event.kind is EventKind.READY:
            self.observe(event.now, {event.zone: True})
        # WARNING: advisory only — not a capacity measurement

    # -- prediction ----------------------------------------------------
    def predict(
        self, now: float, horizon_s: float
    ) -> Dict[str, ZoneForecast]:
        """Per-zone forecast ``horizon_s`` seconds ahead of ``now``."""
        if horizon_s <= 0:
            raise ValueError(f"horizon_s must be positive, got {horizon_s}")
        return {
            z: self._predict_zone(z, now, horizon_s) for z in self._zones
        }

    def _predict_zone(
        self, zone: str, now: float, horizon_s: float
    ) -> ZoneForecast:
        raise NotImplementedError

    # -- shared helpers --------------------------------------------------
    def _siblings(self, zone: str) -> List[str]:
        region = self._region_of.get(zone, infer_region(zone))
        return [
            z for z in self._zones
            if z != zone and self._region_of.get(z) == region
        ]

    @staticmethod
    def _clip(p: float) -> float:
        return min(1.0, max(0.0, float(p)))


# ---------------------------------------------------------------------------
# Registry (mirrors repro.core.policy's)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, type] = {}


def register_forecaster(cls: type) -> type:
    _REGISTRY[cls.name] = cls
    return cls


def _load_builtin() -> None:
    # Import for registration side effects.
    from repro.forecast import estimators as _e  # noqa: F401


def make_forecaster(name: str, **kwargs) -> Forecaster:
    """Instantiate a forecaster by registered name (spec / CLI entry)."""
    _load_builtin()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown forecaster {name!r}; have {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name](**kwargs)


def registered_forecasters() -> List[str]:
    _load_builtin()
    return sorted(_REGISTRY)

"""Spot-availability forecasting: predict zone risk before it bites.

SpotHedge's dynamic placement is reactive — a zone only reaches ``Z_P``
after a preemption or failed launch already cost a replica and a cold
start.  This package closes that loop: :class:`Forecaster` implementations
(persistence baseline, per-zone EWMA hazard, sibling-correlated regional
Markov) turn the observation history the policies already receive into
calibrated per-zone availability scores and preemption-risk estimates;
``repro.core.risk_aware.RiskAwareSpotHedgePolicy`` consumes them to rank
zones and pre-hedge on-demand, and :mod:`repro.forecast.backtest` replays
any trace through a forecaster and scores it (Brier, hit rate,
calibration) into versioned artifacts under ``artifacts/forecast/``.
"""

from repro.forecast.backtest import (
    BacktestReport,
    HorizonScore,
    run_backtest,
)
from repro.forecast.base import (
    Forecaster,
    ZoneForecast,
    infer_region,
    make_forecaster,
    register_forecaster,
    registered_forecasters,
)
from repro.forecast.estimators import (
    EWMAForecaster,
    MarkovRegionalForecaster,
    PersistenceForecaster,
)

__all__ = [
    "BacktestReport",
    "EWMAForecaster",
    "Forecaster",
    "HorizonScore",
    "MarkovRegionalForecaster",
    "PersistenceForecaster",
    "ZoneForecast",
    "infer_region",
    "make_forecaster",
    "register_forecaster",
    "registered_forecasters",
    "run_backtest",
]

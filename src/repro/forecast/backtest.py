"""Backtest a forecaster against a recorded spot trace.

The harness replays a :class:`~repro.cluster.traces.SpotTrace` step by
step: at each step the forecaster observes the realized availability row,
then (past a warmup) predicts every zone's availability and preemption
risk at one or more horizons.  Predictions are scored against what the
trace actually did:

* **Brier score** — mean squared error of ``p_available`` (and of
  ``p_preempt`` against realized preemption events), lower is better;
* **hit rate** — accuracy of the thresholded up/down call vs. horizon;
* **calibration curve** — predicted-probability bins vs. realized
  frequency, the "are 80% forecasts right 80% of the time" check.

Reports serialize to versioned JSON artifacts under
``artifacts/forecast/`` (``schema: 1``), one file per (trace,
forecaster).  CLI::

    PYTHONPATH=src python -m repro.forecast.backtest \
        --trace aws-1 --forecasters persistence ewma markov
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.traces import SpotTrace, load_trace
from repro.forecast.base import (
    Forecaster,
    make_forecaster,
    registered_forecasters,
)

__all__ = [
    "HorizonScore",
    "BacktestReport",
    "run_backtest",
    "main",
]

SCHEMA_VERSION = 1
ART_DIR = os.path.join("artifacts", "forecast")

#: horizons scored by default, in trace steps (5 min / 15 min / 30 min at
#: the usual dt=60s) — the range over which a controller can actually act
#: (a cold start is ~3 min, so sub-5-minute forecasts change nothing)
DEFAULT_HORIZONS = (5, 15, 30)


@dataclasses.dataclass
class HorizonScore:
    """All metrics of one forecast horizon."""

    steps: int
    seconds: float
    n: int                         # scored (step, zone) pairs
    brier_avail: float             # MSE of p_available vs realized up
    brier_preempt: float           # MSE of p_preempt vs realized event
    hit_rate: float                # accuracy of p_available >= 0.5 call
    base_rate: float               # realized availability frequency
    calibration: List[Dict[str, float]]   # [{p_mean, freq, n}, ...]

    def to_dict(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        for k in ("brier_avail", "brier_preempt", "hit_rate", "base_rate"):
            out[k] = round(out[k], 6)
        return out


@dataclasses.dataclass
class BacktestReport:
    """One forecaster's scores over one trace, JSON-serializable."""

    trace: str
    forecaster: str
    dt_s: float
    n_steps: int
    n_zones: int
    warmup_steps: int
    horizons: List[HorizonScore]

    @property
    def mean_brier_avail(self) -> float:
        """Headline number: Brier of p_available averaged over horizons."""
        return float(np.mean([h.brier_avail for h in self.horizons]))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": SCHEMA_VERSION,
            "kind": "forecast-backtest",
            "trace": self.trace,
            "forecaster": self.forecaster,
            "dt_s": self.dt_s,
            "n_steps": self.n_steps,
            "n_zones": self.n_zones,
            "warmup_steps": self.warmup_steps,
            "mean_brier_avail": round(self.mean_brier_avail, 6),
            "horizons": [h.to_dict() for h in self.horizons],
        }

    def save(self, directory: str = ART_DIR,
             stem: Optional[str] = None) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(
            directory,
            f"{stem or f'backtest_{self.trace}_{self.forecaster}'}.json",
        )
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    @staticmethod
    def load(path: str) -> "BacktestReport":
        with open(path) as f:
            d = json.load(f)
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"backtest artifact {path!r} has schema "
                f"{d.get('schema')!r}, expected {SCHEMA_VERSION}"
            )
        return BacktestReport(
            trace=d["trace"],
            forecaster=d["forecaster"],
            dt_s=d["dt_s"],
            n_steps=d["n_steps"],
            n_zones=d["n_zones"],
            warmup_steps=d["warmup_steps"],
            horizons=[HorizonScore(**h) for h in d["horizons"]],
        )

    def summary(self) -> str:
        lines = [
            f"{self.forecaster:>12s} @ {self.trace:<8s} "
            f"mean Brier(avail)={self.mean_brier_avail:.4f}"
        ]
        for h in self.horizons:
            lines.append(
                f"    h={h.seconds / 60.0:5.1f}min "
                f"brier={h.brier_avail:.4f} "
                f"preempt_brier={h.brier_preempt:.4f} "
                f"hit={h.hit_rate:6.2%} base={h.base_rate:6.2%}"
            )
        return "\n".join(lines)


def _calibration(
    preds: np.ndarray, realized: np.ndarray, bins: int = 10
) -> List[Dict[str, float]]:
    """Binned predicted probability vs. realized frequency."""
    out: List[Dict[str, float]] = []
    edges = np.linspace(0.0, 1.0, bins + 1)
    idx = np.clip(np.digitize(preds, edges[1:-1]), 0, bins - 1)
    for b in range(bins):
        mask = idx == b
        n = int(mask.sum())
        if n == 0:
            continue
        out.append(
            {
                "p_mean": round(float(preds[mask].mean()), 6),
                "freq": round(float(realized[mask].mean()), 6),
                "n": n,
            }
        )
    return out


def _zone_regions(trace: SpotTrace) -> Dict[str, str]:
    """Catalog regions where known, heuristic inference otherwise."""
    from repro.cluster.catalog import default_catalog
    from repro.forecast.base import infer_region

    catalog = default_catalog()
    out: Dict[str, str] = {}
    for z in trace.zones:
        try:
            out[z] = catalog.zone(z).region
        except KeyError:
            out[z] = infer_region(z)
    return out


def run_backtest(
    trace: "SpotTrace | str",
    forecaster: "Forecaster | str",
    *,
    horizons: Sequence[int] = DEFAULT_HORIZONS,
    warmup_steps: int = 120,
    max_steps: Optional[int] = None,
) -> BacktestReport:
    """Replay ``trace`` through ``forecaster`` and score every horizon.

    ``warmup_steps`` are observed but not scored (estimators need history
    before their probabilities mean anything).  ``max_steps`` truncates
    the replay — the CI smoke knob.
    """
    if isinstance(trace, str):
        trace = load_trace(trace)
    if isinstance(forecaster, str):
        forecaster = make_forecaster(forecaster)
    horizons = sorted(set(int(h) for h in horizons))
    if not horizons or horizons[0] <= 0:
        raise ValueError(f"horizons must be positive ints, got {horizons}")

    avail = trace.cap > 0                      # bool [T, Z]
    drops = trace.preemption_indicator()       # bool [T, Z]
    T = avail.shape[0] if max_steps is None else min(
        avail.shape[0], int(max_steps)
    )
    zones = list(trace.zones)
    warmup = min(int(warmup_steps), max(T - max(horizons) - 1, 0))
    forecaster.reset(zones, _zone_regions(trace), dt=trace.dt)

    # per horizon: predictions and realizations, accumulated as flat lists
    acc: Dict[int, Dict[str, List[float]]] = {
        h: {"pa": [], "ra": [], "pp": [], "rp": []} for h in horizons
    }
    # cumulative drop counts for O(1) "any preemption in (t, t+h]" queries
    drop_cum = np.cumsum(drops, axis=0)

    for t in range(T):
        now = t * trace.dt
        forecaster.observe(
            now, {z: bool(avail[t, j]) for j, z in enumerate(zones)}
        )
        for h in horizons:
            if t < warmup or t + h >= T:
                continue
            pred = forecaster.predict(now, h * trace.dt)
            for j, z in enumerate(zones):
                a = acc[h]
                a["pa"].append(pred[z].p_available)
                a["ra"].append(float(avail[t + h, j]))
                if avail[t, j]:
                    # preemption risk is only defined for a zone that
                    # could host a running instance now
                    a["pp"].append(pred[z].p_preempt)
                    a["rp"].append(
                        float(drop_cum[t + h, j] - drop_cum[t, j] > 0)
                    )

    scores: List[HorizonScore] = []
    for h in horizons:
        pa = np.asarray(acc[h]["pa"])
        ra = np.asarray(acc[h]["ra"])
        pp = np.asarray(acc[h]["pp"])
        rp = np.asarray(acc[h]["rp"])
        if len(pa) == 0:
            continue
        scores.append(
            HorizonScore(
                steps=h,
                seconds=h * trace.dt,
                n=len(pa),
                brier_avail=float(np.mean((pa - ra) ** 2)),
                brier_preempt=(
                    float(np.mean((pp - rp) ** 2)) if len(pp) else 0.0
                ),
                hit_rate=float(np.mean((pa >= 0.5) == (ra > 0.5))),
                base_rate=float(ra.mean()),
                calibration=_calibration(pa, ra),
            )
        )
    if not scores:
        raise ValueError(
            f"trace {trace.name!r} too short to score: {T} steps with "
            f"warmup {warmup} and horizons {horizons}"
        )
    return BacktestReport(
        trace=trace.name,
        forecaster=forecaster.name,
        dt_s=trace.dt,
        n_steps=T,
        n_zones=len(zones),
        warmup_steps=warmup,
        horizons=scores,
    )


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Backtest spot-availability forecasters on a trace"
    )
    ap.add_argument("--trace", default="aws-1",
                    help="named dataset or .json/.npz trace path")
    ap.add_argument("--forecasters", nargs="+", default=None,
                    help=f"default: all ({registered_forecasters()})")
    ap.add_argument("--horizons", nargs="+", type=int,
                    default=list(DEFAULT_HORIZONS),
                    help="forecast horizons in trace steps")
    ap.add_argument("--warmup", type=int, default=120,
                    help="steps observed before scoring starts")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="truncate the replay (CI smoke)")
    ap.add_argument("--out-dir", default=ART_DIR)
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)
    names = args.forecasters or registered_forecasters()
    for name in names:
        report = run_backtest(
            trace,
            name,
            horizons=args.horizons,
            warmup_steps=args.warmup,
            max_steps=args.max_steps,
        )
        path = report.save(args.out_dir)
        print(report.summary())
        print(f"  -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

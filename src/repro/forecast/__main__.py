"""CLI entry: ``python -m repro.forecast`` runs the backtest harness."""

import sys

from repro.forecast.backtest import main

if __name__ == "__main__":
    sys.exit(main())

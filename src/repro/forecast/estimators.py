"""The built-in forecasters: persistence, EWMA, regional Markov.

All three consume the same binary availability observations and emit
:class:`~repro.forecast.base.ZoneForecast` scores; they differ in how much
structure they extract from the history:

* :class:`PersistenceForecaster` — the classic no-skill baseline: whatever
  a zone did last, it keeps doing.  Hard 0/1 probabilities; every state
  flip inside the horizon costs it a full Brier point, which is exactly
  why it is the bar the learned estimators must clear.
* :class:`EWMAForecaster` — per-zone exponentially-weighted availability
  mean and preemption (down-transition) hazard.  Forecasts decay from the
  zone's current state toward its long-run average as the horizon grows.
* :class:`MarkovRegionalForecaster` — per-zone 2-state Markov chain with
  online-estimated transition probabilities, conditioned on whether any
  *sibling* zone of the same region is currently down.  Regional capacity
  crunches hit sibling zones together (Fig. 3), so the crunch-conditioned
  bucket learns a much higher down-hazard — the cross-zone signal neither
  simpler estimator can represent.
"""

from __future__ import annotations

import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.forecast.base import (
    Forecaster,
    ZoneForecast,
    register_forecaster,
)

__all__ = [
    "PersistenceForecaster",
    "EWMAForecaster",
    "MarkovRegionalForecaster",
]


class _ZoneStateMixin(Forecaster):
    """Shared per-zone last-observed-state bookkeeping."""

    def reset(self, zones, zone_region=None, dt: float = 60.0) -> None:
        super().reset(zones, zone_region, dt)
        self._state: Dict[str, Optional[bool]] = {z: None for z in zones}
        self._seen_at: Dict[str, float] = {}

    def _note(self, now: float, zone: str, up: bool) -> None:
        self._state[zone] = up
        self._seen_at[zone] = now


@register_forecaster
class PersistenceForecaster(_ZoneStateMixin):
    """Predict that the last observed state persists indefinitely.

    ``prior`` is returned for zones never observed (0.5 = "no idea").
    """

    name = "persistence"

    def __init__(self, prior: float = 0.5) -> None:
        super().__init__()
        self.prior = float(prior)
        if not 0.0 <= self.prior <= 1.0:
            raise ValueError(f"prior must be a probability, got {prior}")

    def observe(self, now: float, available: Mapping[str, bool]) -> None:
        for zone, up in available.items():
            if zone in self._state:
                self._note(now, zone, bool(up))

    def _predict_zone(
        self, zone: str, now: float, horizon_s: float
    ) -> ZoneForecast:
        s = self._state[zone]
        if s is None:
            p_avail = self.prior
        else:
            p_avail = 1.0 if s else 0.0
        # persistence claims nothing ever changes: a running instance is
        # never preempted unless the zone is already observed down
        return ZoneForecast(
            zone=zone,
            p_available=p_avail,
            p_preempt=1.0 - p_avail,
        )


@register_forecaster
class EWMAForecaster(_ZoneStateMixin):
    """Per-zone EW availability mean + EW preemption hazard.

    State updates use irregular-interval exponential decay (the policy
    path observes zones at uneven times), expressed via half-lives:

    * ``halflife_s``     — memory of the availability mean;
    * ``mix_halflife_s`` — how fast a forecast relaxes from the current
      state toward the long-run mean as the horizon grows;
    * the hazard estimator counts down-transitions per second of observed
      up-time, decayed with ``halflife_s``.
    """

    name = "ewma"

    def __init__(
        self,
        halflife_s: float = 6 * 3600.0,
        mix_halflife_s: float = 1800.0,
        prior: float = 0.5,
    ) -> None:
        super().__init__()
        if halflife_s <= 0 or mix_halflife_s <= 0:
            raise ValueError("half-lives must be positive")
        if not 0.0 <= prior <= 1.0:
            raise ValueError(f"prior must be a probability, got {prior}")
        self.halflife_s = float(halflife_s)
        self.mix_halflife_s = float(mix_halflife_s)
        self.prior = float(prior)

    def reset(self, zones, zone_region=None, dt: float = 60.0) -> None:
        super().reset(zones, zone_region, dt)
        self._mean: Dict[str, float] = {z: self.prior for z in zones}
        # EW (down-transition count, observed up-seconds) per zone
        self._haz_events: Dict[str, float] = {z: 0.0 for z in zones}
        self._haz_time: Dict[str, float] = {z: 0.0 for z in zones}

    def observe(self, now: float, available: Mapping[str, bool]) -> None:
        ln2 = math.log(2.0)
        for zone, up_raw in available.items():
            if zone not in self._state:
                continue
            up = bool(up_raw)
            prev = self._state[zone]
            # same-instant duplicates (k preemptions of one zone arrive as
            # k events at one tick): latest evidence wins, but only one
            # observation may move the statistics or the k-1 repeats
            # masquerade as extra dt-spaced steps
            if prev is not None and now <= self._seen_at.get(zone, now):
                self._note(now, zone, up)
                continue
            gap = max(now - self._seen_at.get(zone, now), 0.0)
            decay = math.exp(-ln2 * gap / self.halflife_s)
            w = 1.0 - math.exp(-ln2 * max(gap, self._dt) / self.halflife_s)
            self._mean[zone] += w * ((1.0 if up else 0.0) - self._mean[zone])
            self._haz_events[zone] *= decay
            self._haz_time[zone] *= decay
            if prev is True:
                # the elapsed gap was observed up-time; a flip to down is
                # one preemption event in that exposure window
                self._haz_time[zone] += max(gap, self._dt)
                if not up:
                    self._haz_events[zone] += 1.0
            self._note(now, zone, up)

    def _hazard(self, zone: str) -> float:
        """Down-transitions per second of up-time (with a weak prior of
        one event per week so unseen zones aren't scored risk-free)."""
        prior_events, prior_time = 1.0, 7 * 24 * 3600.0
        return (self._haz_events[zone] + prior_events) / (
            self._haz_time[zone] + prior_time
        )

    def _predict_zone(
        self, zone: str, now: float, horizon_s: float
    ) -> ZoneForecast:
        s = self._state[zone]
        mean = self._clip(self._mean[zone])
        if s is None:
            p_avail = mean
        else:
            # relax from the current state toward the long-run mean over
            # the *effective* horizon (staleness since last sighting
            # counts — old knowledge is worth less)
            h_eff = horizon_s + max(now - self._seen_at.get(zone, now), 0.0)
            w = math.exp(-math.log(2.0) * h_eff / self.mix_halflife_s)
            p_avail = self._clip(w * (1.0 if s else 0.0) + (1.0 - w) * mean)
        if s is False:
            p_preempt = 1.0
        else:
            p_preempt = self._clip(
                1.0 - math.exp(-self._hazard(zone) * horizon_s)
            )
        return ZoneForecast(
            zone=zone, p_available=p_avail, p_preempt=p_preempt
        )


@register_forecaster
class MarkovRegionalForecaster(_ZoneStateMixin):
    """Online 2-state Markov chain per zone, sibling-crunch conditioned.

    Transition statistics are kept in two buckets per zone: *calm* (no
    sibling zone of the same region observed down) and *crunch* (at least
    one sibling down).  Each bucket's up->down probability ``p`` and
    down->up probability ``q`` is estimated with hierarchical smoothing —
    bucket counts shrink toward the zone's pooled estimate, which shrinks
    toward a weak global prior — so the crunch bucket only departs from
    the calm one once the data shows sibling correlation.

    Prediction uses the closed-form n-step transition of the 2-state
    chain: with ``r = 1 - p - q`` and stationary availability
    ``pi = q / (p + q)``,

        P(up at n | up now)   = pi + (1 - pi) * r**n
        P(up at n | down now) = pi - pi * r**n

    Staleness folds in naturally: ``n`` counts steps since the zone was
    last *observed*, so an old sighting decays toward ``pi``.
    """

    name = "markov"

    #: pseudo-count strength of the bucket->pooled and pooled->global
    #: shrinkage, in observations
    smoothing: float = 20.0
    #: weak global priors: rare transitions in both directions
    prior_p_down: float = 0.02      # up -> down per step
    prior_p_up: float = 0.10        # down -> up per step

    def __init__(self, smoothing: Optional[float] = None) -> None:
        super().__init__()
        if smoothing is not None:
            if smoothing <= 0:
                raise ValueError("smoothing must be positive")
            self.smoothing = float(smoothing)

    def reset(self, zones, zone_region=None, dt: float = 60.0) -> None:
        super().reset(zones, zone_region, dt)
        # counts[zone][bucket] = [n_uu, n_ud, n_dd, n_du]
        self._counts: Dict[str, Dict[str, list]] = {
            z: {"calm": [0.0] * 4, "crunch": [0.0] * 4} for z in zones
        }
        self._sibs: Dict[str, Tuple[str, ...]] = {
            z: tuple(self._siblings(z)) for z in zones
        }
        # smoothed (p, q) per (zone, bucket), invalidated on observe —
        # predict() is called once per horizon per backtest step, and
        # the hierarchical smoothing is the dominant cost
        self._rates_cache: Dict[Tuple[str, str], Tuple[float, float]] = {}

    # -- state updates ---------------------------------------------------
    def _bucket(self, zone: str) -> str:
        return (
            "crunch"
            if any(self._state[s] is False for s in self._sibs[zone])
            else "calm"
        )

    def observe(self, now: float, available: Mapping[str, bool]) -> None:
        # condition on sibling states *before* this row lands, so a
        # simultaneous region-wide drop is attributed to the calm bucket
        # (the first domino) while the crunch bucket captures persistence
        # and follow-on drops — the predictive part of the correlation
        self._rates_cache.clear()
        buckets = {
            z: self._bucket(z) for z in available if z in self._state
        }
        for zone, up_raw in available.items():
            if zone not in self._state:
                continue
            up = bool(up_raw)
            prev = self._state[zone]
            gap = now - self._seen_at.get(zone, now)
            # 0 < gap: same-instant duplicate events must not count as
            # extra dt-spaced transitions; <= 3 dt: stale pairs carry no
            # per-step transition information
            if prev is not None and 0.0 < gap <= 3.0 * self._dt:
                c = self._counts[zone][buckets[zone]]
                if prev and up:
                    c[0] += 1.0
                elif prev and not up:
                    c[1] += 1.0
                elif not prev and not up:
                    c[2] += 1.0
                else:
                    c[3] += 1.0
            self._note(now, zone, up)

    # -- estimation ------------------------------------------------------
    def _rates(self, zone: str, bucket: str) -> Tuple[float, float]:
        """(p, q) = (up->down, down->up) per-step probabilities for the
        zone under ``bucket``, hierarchically smoothed (memoized until
        the next observation)."""
        cached = self._rates_cache.get((zone, bucket))
        if cached is not None:
            return cached
        w = self.smoothing
        pooled = [0.0] * 4
        for b in ("calm", "crunch"):
            for i, v in enumerate(self._counts[zone][b]):
                pooled[i] += v
        p_pool = (pooled[1] + w * self.prior_p_down) / (
            pooled[0] + pooled[1] + w
        )
        q_pool = (pooled[3] + w * self.prior_p_up) / (
            pooled[2] + pooled[3] + w
        )
        c = self._counts[zone][bucket]
        p = (c[1] + w * p_pool) / (c[0] + c[1] + w)
        q = (c[3] + w * q_pool) / (c[2] + c[3] + w)
        eps = 1e-6
        out = (min(max(p, eps), 1.0 - eps), min(max(q, eps), 1.0 - eps))
        self._rates_cache[(zone, bucket)] = out
        return out

    # -- prediction ------------------------------------------------------
    def _predict_zone(
        self, zone: str, now: float, horizon_s: float
    ) -> ZoneForecast:
        p, q = self._rates(zone, self._bucket(zone))
        pi = q / (p + q)
        r = 1.0 - p - q
        s = self._state[zone]
        stale_s = max(now - self._seen_at.get(zone, now), 0.0)
        n = max(1, int(round((horizon_s + stale_s) / self._dt)))
        if s is None:
            p_avail = pi
        elif s:
            p_avail = pi + (1.0 - pi) * r ** n
        else:
            p_avail = pi - pi * r ** n
        # preemption risk of an instance running *now*: survival of the
        # up state over the horizon itself (staleness excluded — the live
        # instance is the freshest possible up-observation)
        n_h = max(1, int(round(horizon_s / self._dt)))
        p_preempt = 1.0 - (1.0 - p) ** n_h
        if s is False:
            p_preempt = 1.0
        return ZoneForecast(
            zone=zone,
            p_available=self._clip(p_avail),
            p_preempt=self._clip(p_preempt),
        )

    # -- introspection (tests / dashboards) ------------------------------
    def rates(self, zone: str) -> Dict[str, Tuple[float, float]]:
        """Smoothed (p, q) per bucket for one zone."""
        return {b: self._rates(zone, b) for b in ("calm", "crunch")}

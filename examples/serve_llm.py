"""End-to-end serving driver: a REAL JAX engine fleet behind SpotHedge.

Two live replicas (reduced llama3.2 backbones) serve batched requests
through the least-loaded balancer while a preemption is injected mid-run —
the in-flight requests of the killed replica are retried client-side on the
survivor, exactly the paper's §4 "Preemption handling" semantics.

    PYTHONPATH=src python examples/serve_llm.py
"""

import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.service import spec_from_dict

# The fleet is declared the same way the simulated paths are — one spec;
# the live driver reads the model + replica count from it.
SPEC = spec_from_dict({
    "name": "serve-llm-live",
    "model": "llama3.2-1b",
    "trace": "aws-3",
    "replica_policy": {"name": "spothedge"},
    "autoscaler": {"kind": "constant", "target": 2},
    "workload": {"kind": "none"},
})


class LiveReplica:
    """A real prefill+decode engine with slot-based continuous batching."""

    def __init__(self, name: str, cfg, model, params, max_batch=4,
                 max_len=96):
        self.name, self.cfg, self.model, self.params = (
            name, cfg, model, params
        )
        self.alive = True
        self._prefill = jax.jit(
            lambda p, t, c: model.prefill(p, t, c)
        )
        self._decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
        self.max_len = max_len
        self.inflight = []           # (req_id, cache, tok, remaining)

    def submit(self, req_id: int, prompt, out_tokens: int):
        cache = self.model.init_cache(1, self.max_len)
        logits, cache = self._prefill(self.params, prompt[None], cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        self.inflight.append([req_id, cache, tok, out_tokens, [int(tok[0, 0])]])

    def step(self):
        """One decode step for every in-flight request."""
        done = []
        still = []
        for item in self.inflight:
            req_id, cache, tok, remaining, out = item
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
            remaining -= 1
            if remaining <= 0:
                done.append((req_id, out))
            else:
                still.append([req_id, cache, tok, remaining, out])
        self.inflight = still
        return done

    def kill(self):
        """Preemption: drop in-flight work, return ids for client retry."""
        self.alive = False
        failed = [item[0] for item in self.inflight]
        self.inflight = []
        return failed


def main():
    cfg = get_smoke_config(SPEC.model)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reps = [LiveReplica(f"replica-{i}", cfg, model, params)
            for i in range(SPEC.autoscaler.target)]

    rng = jax.random.PRNGKey(7)
    prompts = {
        i: jax.random.randint(jax.random.fold_in(rng, i), (12,), 0,
                              cfg.vocab_size)
        for i in range(8)
    }
    pending = list(prompts)
    completed, retried = {}, []

    t0 = time.time()
    step = 0
    while len(completed) < len(prompts):
        ready = [r for r in reps if r.alive]
        # least-loaded dispatch of pending requests
        while pending and ready:
            req = pending.pop(0)
            target = min(ready, key=lambda r: len(r.inflight))
            target.submit(req, prompts[req], out_tokens=16)
            print(f"[lb] request {req} -> {target.name}")
        for r in ready:
            for req_id, out in r.step():
                completed[req_id] = out
                print(f"[{r.name}] request {req_id} done "
                      f"({len(out)} tokens)")
        step += 1
        if step == 4 and reps[0].alive:
            failed = reps[0].kill()
            print(f"[cloud] PREEMPTION kills {reps[0].name}; "
                  f"retrying {failed} on survivors (client-side retry)")
            pending = failed + pending
    dt = time.time() - t0
    tok_total = sum(len(v) for v in completed.values())
    print(f"\nserved {len(completed)} requests / {tok_total} tokens "
          f"in {dt:.1f}s across a preemption — zero lost requests")


if __name__ == "__main__":
    main()

"""Training driver: ~100M-param llama-family model, synthetic data,
checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_100m.py --steps 30
    # kill it mid-run, rerun the same command: it resumes from the last
    # atomic checkpoint (the SpotHedge training-side story).

A full few-hundred-step run is `--steps 300` (CPU: ~minutes to hours
depending on the machine; the loop and checkpoints are the point here —
the production mesh path is exercised by the multi-pod dry-run).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.models import build_model, param_count
from repro.training import AdamWConfig, adamw_init, make_train_step
from repro.training.data import make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="artifacts/train_100m")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    # ~100M params: llama-family, reduced dims
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        name="llama-100m",
        num_layers=10,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
    )
    model = build_model(cfg, remat=False)
    n = param_count(model.blueprint())
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20,
                          total_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg, microbatches=1))

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt_state": opt_state}
        restored, start = restore_checkpoint(args.ckpt_dir, tree)
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"resumed from checkpoint step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_batch(cfg, args.batch, args.seq, seed=1, step=step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step:4d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"({(time.time()-t0)/(step-start+1):.1f}s/step)")
        if (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1, params,
                                   opt_state)
            print(f"checkpointed -> {path}")
    print("done")


if __name__ == "__main__":
    main()

"""Quickstart: run SpotHedge against a recorded spot trace.

    PYTHONPATH=src python examples/quickstart.py

Launches a 4-replica service on the GCP A100 trace (volatile!), lets
SpotHedge place spot replicas across zones/regions with on-demand
fallback, and prints availability + cost vs an all-on-demand deployment.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster.simulator import run_policy_on_trace
from repro.cluster.traces import TraceLibrary

trace = TraceLibrary().get("gcp-1")          # 3-day a2-ultragpu-4g trace
print(f"trace {trace.name}: {len(trace.zones)} zones, "
      f"{trace.duration_s/3600:.0f}h")

for policy in ("spothedge", "even_spread", "round_robin", "ondemand_only"):
    res = run_policy_on_trace(
        policy, trace, n_target=4, itype="a2-ultragpu-4g",
        control_interval_s=30.0,
    )
    print(res.summary())

print("\nSpotHedge keeps availability near on-demand at a fraction of the "
      "cost —\nthe paper's Fig. 14a/14b result.")

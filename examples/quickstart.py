"""Quickstart: declare a service, run SpotHedge against a recorded trace.

    PYTHONPATH=src python examples/quickstart.py

Declares a 4-replica service on the GCP A100 trace (volatile!) as a
ServiceSpec — the paper's Listing 1 — then swaps the replica policy to
compare SpotHedge against the baselines on availability + cost vs an
all-on-demand deployment.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.service import ReplicaPolicySpec, Service, spec_from_dict

spec = spec_from_dict({
    "name": "quickstart",
    "model": "llama3.2-1b",
    "trace": "gcp-1",                    # 3-day a2-ultragpu-4g trace
    "resources": {
        "instance_type": "a2-ultragpu-4g",
        "any_of": [                      # Listing 1: us + eu GCP regions
            {"region": "us-central1"},
            {"region": "us-west1"},
            {"region": "europe-west4"},
        ],
    },
    "replica_policy": {"name": "spothedge", "overprovision": 2},
    "autoscaler": {"kind": "constant", "target": 4},
    "workload": {"kind": "none"},        # control plane only (Fig. 14)
    "sim": {"duration_hours": 72.0, "control_interval_s": 30.0},
})

svc = Service(spec)
resolved = svc.resolve()
print(f"trace {resolved.trace.name}: {len(resolved.zones)} zones, "
      f"{resolved.trace.duration_s/3600:.0f}h")

for policy in ("spothedge", "even_spread", "round_robin", "ondemand_only"):
    variant = dataclasses.replace(
        spec, replica_policy=ReplicaPolicySpec(name=policy)
    )
    res = Service(variant).run()
    print(f"{policy:>16s}  avail={res.availability:6.2%} "
          f"cost={res.cost_vs_ondemand:6.2%} of OD "
          f"preempt={res.n_preemptions:4d}")

print("\nSpotHedge keeps availability near on-demand at a fraction of the "
      "cost —\nthe paper's Fig. 14a/14b result.")

"""Policy comparison across all four spot traces (Fig. 14 in miniature),
including the Omniscient ILP lower bound — every run declared as a
ServiceSpec variant of one base spec.

    PYTHONPATH=src python examples/policy_comparison.py [--full]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.service import ReplicaPolicySpec, Service, spec_from_dict

FULL = "--full" in sys.argv
ITYPES = {"aws-1": "p3.2xlarge", "aws-2": "p3.2xlarge",
          "aws-3": "p3.2xlarge", "gcp-1": "a2-ultragpu-4g"}

BASE = spec_from_dict({
    "name": "policy-comparison",
    "model": "llama3.2-1b",
    "autoscaler": {"kind": "constant", "target": 4},
    "workload": {"kind": "none"},
    "sim": {"duration_hours": 96.0, "control_interval_s": 30.0},
})

print(f"{'policy':>16s} {'trace':>7s} {'avail':>7s} {'cost/OD':>8s} "
      f"{'preempt':>8s}")
for tname in ("aws-1", "aws-2", "aws-3", "gcp-1"):
    for pol in ("even_spread", "round_robin", "spothedge", "omniscient"):
        spec = dataclasses.replace(
            BASE,
            trace=tname,
            resources=dataclasses.replace(
                BASE.resources, instance_type=ITYPES[tname]
            ),
            replica_policy=ReplicaPolicySpec(name=pol),
        )
        svc = Service(spec)
        trace = svc.resolve().trace
        dur = trace.duration_s if FULL else min(
            trace.duration_s, 4 * 86_400.0
        )
        res = svc.run(dur)
        print(f"{pol:>16s} {tname:>7s} {res.availability:7.2%} "
              f"{res.cost_vs_ondemand:8.2%} {res.n_preemptions:8d}")

"""Policy comparison across all four spot traces (Fig. 14 in miniature),
including the Omniscient ILP lower bound.

    PYTHONPATH=src python examples/policy_comparison.py [--full]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster.simulator import run_policy_on_trace
from repro.cluster.traces import TraceLibrary

FULL = "--full" in sys.argv
ITYPES = {"aws-1": "p3.2xlarge", "aws-2": "p3.2xlarge",
          "aws-3": "p3.2xlarge", "gcp-1": "a2-ultragpu-4g"}

lib = TraceLibrary()
print(f"{'policy':>16s} {'trace':>7s} {'avail':>7s} {'cost/OD':>8s} "
      f"{'preempt':>8s}")
for tname in ("aws-1", "aws-2", "aws-3", "gcp-1"):
    tr = lib.get(tname)
    dur = None if FULL else min(tr.duration_s, 4 * 86_400.0)
    for pol in ("even_spread", "round_robin", "spothedge", "omniscient"):
        res = run_policy_on_trace(
            pol, tr, n_target=4, itype=ITYPES[tname],
            control_interval_s=30.0, duration_s=dur,
        )
        print(f"{pol:>16s} {tname:>7s} {res.availability:7.2%} "
              f"{res.cost_vs_ondemand:8.2%} {res.n_preemptions:8d}")

"""Quickstart: forecast spot availability, place replicas risk-aware.

    PYTHONPATH=src python examples/risk_aware.py

Three steps:

1. inspect a trace's per-zone availability / preemption / correlation
   stats (the signal the forecasters feed on);
2. backtest the forecasters on that trace — the regional-Markov
   estimator should beat the persistence baseline on Brier score;
3. run vanilla SpotHedge vs. risk-aware SpotHedge end to end on the
   same trace and compare availability, cost, and preemptions.
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.cluster.traces import load_trace, trace_stats
from repro.forecast import run_backtest
from repro.service import Service, spec_from_dict

TRACE = "aws-1"

# -- 1) what the forecasters see --------------------------------------------
stats = trace_stats(load_trace(TRACE))
print(f"{TRACE}: mean availability {stats['mean_availability']:.1%}")
for zone, s in stats["zones"].items():
    print(f"  {zone:<14s} avail={s['availability']:6.1%} "
          f"preempt/day={s['preemptions_per_day']:5.1f} "
          f"sibling r={s['mean_sibling_corr']:.2f}")

# -- 2) can the predictors beat persistence? --------------------------------
print("\nbacktest (Brier of the availability forecast, lower is better):")
for name in ("persistence", "ewma", "markov"):
    report = run_backtest(TRACE, name)
    print(f"  {name:<12s} mean Brier = {report.mean_brier_avail:.4f}")

# -- 3) does it pay off end to end? -----------------------------------------
base = spec_from_dict({
    "name": "risk-aware-demo",
    "model": "llama3.2-1b",
    "trace": TRACE,
    "resources": {"instance_type": "p3.2xlarge"},
    "replica_policy": {"name": "spothedge"},
    "autoscaler": {"kind": "constant", "target": 4},
    "workload": {"kind": "none"},            # availability/cost focus
    "forecast": {"name": "markov"},          # consumed by risk_spothedge
    "sim": {"duration_hours": 96.0, "control_interval_s": 30.0,
            "drain_s": 0.0},
})

print("\nend to end (96h, N_Tar=4):")
for policy in ("spothedge", "risk_spothedge"):
    spec = dataclasses.replace(
        base, replica_policy=dataclasses.replace(
            base.replica_policy, name=policy
        ),
    )
    res = Service(spec).run()
    print(f"  {policy:<16s} avail={res.availability:.2%} "
          f"cost={res.cost_vs_ondemand:.1%} of OD "
          f"preempt={res.n_preemptions}")

"""Deliverable (g): roofline table per (arch × shape × mesh) from the
dry-run artifacts.  Single-pod rows are the §Roofline table; multi-pod rows
prove the pod axis shards."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit_csv, save

DRYRUN = os.path.join("artifacts", "dryrun")


def load_records(mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for mesh in ("single", "multi"):
        for r in load_records(mesh):
            if r["status"] != "run":
                rows.append(
                    {
                        "arch": r["arch"],
                        "shape": r["shape"],
                        "mesh": mesh,
                        "status": r["status"],
                    }
                )
                continue
            roof = r["roofline"]
            rows.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "mesh": mesh,
                    "status": "ok",
                    "compute_s": f"{roof['compute_s']:.3e}",
                    "memory_s": f"{roof['memory_s']:.3e}",
                    "collective_s": f"{roof['collective_s']:.3e}",
                    "bottleneck": roof["bottleneck"],
                    "useful_flops_frac": round(
                        roof["useful_flops_fraction"], 3
                    ),
                    "mfu_at_roofline": round(roof["mfu_at_roofline"], 4),
                    "hbm_gib": round(
                        r.get("hbm_bytes_per_chip", 0) / 2**30, 2
                    ),
                    "fits_16gib": r.get("fits_hbm_16gib"),
                }
            )
    if not rows:
        print("roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
    save("roofline", rows)
    emit_csv("roofline", rows)
    return rows


if __name__ == "__main__":
    run()

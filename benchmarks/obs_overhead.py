"""Observability overhead: the same matrix at detail off/decisions/full.

Runs one policy×trace scenario matrix through the vectorized engine
three times — observability ``off``, ``decisions`` (the shipped
default) and ``full`` — and records serial matrix wall-clock to
``artifacts/bench/obs_overhead.json``.  Cell metrics are asserted
identical across detail levels first (recording is pure observation;
the same guarantee tests/test_obs.py pins per-run), so the timing
comparison is apples-to-apples.  The headline number is the default
detail's relative overhead, which must stay under the 5% budget.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks.common import emit_csv, save
from repro.experiments import ScenarioSuite
from repro.service import spec_from_dict
from repro.service.spec import ObservabilitySpec

#: default-detail overhead budget (fraction of the detail=off wall-clock)
BUDGET = 0.05

# cell fields that legitimately differ across detail levels
_NONMETRIC = ("wall_s", "metrics", "obs_event_counts", "obs_windows",
              "slo_burn", "n_spans")


def _base_spec(hours: float):
    return spec_from_dict({
        "name": "obs-overhead",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 0.8, "seed": 3},
        "sim": {"duration_hours": hours, "timeout_s": 60.0,
                "concurrency": 2},
        "sweep": {"policies": ["spothedge", "even_spread"],
                  "traces": ["aws-1", "gcp-1"]},
    })


def _strip(cells) -> List[Dict]:
    return [
        {k: v for k, v in c.to_dict(round_to=None).items()
         if k not in _NONMETRIC}
        for c in cells
    ]


def run(hours: float = 8.0, quick: bool = False) -> List[Dict]:
    trials = 1 if quick else 3
    if quick:
        hours = 4.0
    reports = {}
    for detail in ("off", "decisions", "full"):
        spec = dataclasses.replace(
            _base_spec(hours),
            observability=ObservabilitySpec(detail=detail),
        )
        suite = ScenarioSuite.from_spec(spec)
        # min over trials: wall-clock on shared machines is noisy upward
        reports[detail] = min(
            (suite.run(workers=1) for _ in range(trials)),
            key=lambda r: r.wall_s,
        )

    base = reports["off"]
    for detail in ("decisions", "full"):
        if _strip(base.cells) != _strip(reports[detail].cells):
            raise AssertionError(
                f"observability detail {detail!r} changed cell metrics — "
                "recording must be pure observation"
            )

    rows: List[Dict] = []
    for detail in ("decisions", "full"):
        rep = reports[detail]
        overhead = rep.wall_s / base.wall_s - 1.0
        rows.append({
            "metric": "obs_matrix_overhead",
            "detail": detail,
            "hours": hours,
            "n_cells": len(rep),
            "off_wall_s": round(base.wall_s, 3),
            "wall_s": round(rep.wall_s, 3),
            "overhead_frac": round(overhead, 4),
            "n_events": sum(
                sum((c.obs_event_counts or {}).values())
                for c in rep.cells
            ),
            "metrics_identical": True,
            "budget_frac": BUDGET,
            "within_budget": overhead < BUDGET,
        })

    default_row = rows[0]
    if not default_row["within_budget"]:
        raise AssertionError(
            f"default observability detail costs "
            f"{default_row['overhead_frac']:.1%} matrix wall-clock — "
            f"over the {BUDGET:.0%} budget"
        )

    save("obs_overhead", rows)
    emit_csv("obs_overhead", rows)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hours", type=float, default=8.0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(hours=args.hours, quick=args.quick)

"""Fig. 3 + Fig. 5: preemption correlation structure + availability vs
search-space size."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import emit_csv, save
from repro.cluster.traces import TraceLibrary


def _region_of(z: str) -> str:
    return z.rsplit("-", 1)[0] if (z[-1].isdigit() or z[-2] == "-") \
        else z[:-1]


def run(quick: bool = False) -> List[Dict]:
    lib = TraceLibrary()
    rows: List[Dict] = []
    for name in ("aws-1", "aws-2", "aws-3", "gcp-1"):
        tr = lib.get(name)
        corr = tr.zone_correlation()
        regions = [_region_of(z) for z in tr.zones]
        intra, inter = [], []
        for i in range(len(tr.zones)):
            for j in range(i + 1, len(tr.zones)):
                (intra if regions[i] == regions[j] else inter).append(
                    corr[i, j]
                )
        # Fig. 5: union availability as the search space widens
        unions = {}
        uniq_regions = sorted(set(regions))
        zone1 = (tr.cap[:, :1] > 0).mean()
        r1_idx = [k for k, r in enumerate(regions) if r == uniq_regions[0]]
        region1 = (tr.cap[:, r1_idx] > 0).any(axis=1).mean()
        all_z = (tr.cap > 0).any(axis=1).mean()
        rows.append(
            {
                "trace": name,
                "zones": len(tr.zones),
                "regions": len(uniq_regions),
                "intra_region_corr": round(float(np.mean(intra)), 3)
                if intra
                else None,
                "inter_region_corr": round(float(np.mean(inter)), 3)
                if inter
                else None,
                "avail_one_zone": round(float(zone1), 3),
                "avail_one_region": round(float(region1), 3),
                "avail_all": round(float(all_z), 3),
            }
        )
    save("correlation", rows)
    emit_csv("correlation", rows)
    return rows


if __name__ == "__main__":
    run()

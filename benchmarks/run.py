"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything (quick)
    PYTHONPATH=src python -m benchmarks.run --full     # full durations
    PYTHONPATH=src python -m benchmarks.run --only cost,latency
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    availability,
    correlation,
    cost,
    e2e_compare,
    engine_bench,
    engine_speedup,
    jax_engine,
    latency,
    migration,
    roofline,
    sensitivity,
    token_engine,
)

MODULES = {
    "correlation": correlation,      # Fig. 3 + Fig. 5
    "availability": availability,    # Fig. 14a
    "cost": cost,                    # Fig. 14b
    "e2e_compare": e2e_compare,      # Fig. 9/10/13
    "latency": latency,              # Fig. 15
    "sensitivity": sensitivity,      # Fig. 14c/d
    "engine_bench": engine_bench,    # Fig. 6
    "engine_speedup": engine_speedup,  # legacy vs vector matrix timing
    "jax_engine": jax_engine,        # jit/vmap batched matrix throughput
    "roofline": roofline,            # deliverable (g)
    "token_engine": token_engine,    # request- vs token-level replicas
    "migration": migration,          # grace-period KV migration off/on
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--full", action="store_true",
                    help="full trace durations (slow)")
    args = ap.parse_args(argv)
    names = (
        [n.strip() for n in args.only.split(",") if n.strip()]
        if args.only
        else list(MODULES)
    )
    failures = []
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        print(f"### bench {name} ###", flush=True)
        try:
            mod.run(quick=not args.full)
            print(f"### bench {name} done in {time.time()-t0:.1f}s ###",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("FAILURES:", failures)
        return 1
    print("all benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

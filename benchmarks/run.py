"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything (quick)
    PYTHONPATH=src python -m benchmarks.run --full     # full durations
    PYTHONPATH=src python -m benchmarks.run --only cost,latency

Perf-trajectory tracking: ``--record`` appends one schema-v1 entry per
benchmark (name, wall-clock seconds, git SHA, timestamp) to
``artifacts/bench/trajectory.jsonl``; ``--compare`` gates the run
against each benchmark's previous recorded wall time and fails when one
regresses by more than 20 %.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

from benchmarks import (
    availability,
    correlation,
    cost,
    e2e_compare,
    engine_bench,
    engine_speedup,
    jax_engine,
    latency,
    migration,
    roofline,
    sensitivity,
    token_engine,
)

MODULES = {
    "correlation": correlation,      # Fig. 3 + Fig. 5
    "availability": availability,    # Fig. 14a
    "cost": cost,                    # Fig. 14b
    "e2e_compare": e2e_compare,      # Fig. 9/10/13
    "latency": latency,              # Fig. 15
    "sensitivity": sensitivity,      # Fig. 14c/d
    "engine_bench": engine_bench,    # Fig. 6
    "engine_speedup": engine_speedup,  # legacy vs vector matrix timing
    "jax_engine": jax_engine,        # jit/vmap batched matrix throughput
    "roofline": roofline,            # deliverable (g)
    "token_engine": token_engine,    # request- vs token-level replicas
    "migration": migration,          # grace-period KV migration off/on
}


TRAJECTORY_SCHEMA = 1
TRAJECTORY_PATH = os.path.join("artifacts", "bench", "trajectory.jsonl")
REGRESSION_PCT = 20.0


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def load_baselines(path: str) -> Dict[str, float]:
    """Latest recorded wall time per benchmark name."""
    base: Dict[str, float] = {}
    if not os.path.exists(path):
        return base
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            if r.get("metric") == "wall_s":
                base[str(r["benchmark"])] = float(r["value"])
    return base


def record_entry(path: str, name: str, wall_s: float,
                 sha: Optional[str]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    entry = {
        "schema": TRAJECTORY_SCHEMA,
        "benchmark": name,
        "metric": "wall_s",
        "value": round(wall_s, 3),
        "sha": sha,
        "ts": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True,
                           separators=(",", ":")) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--full", action="store_true",
                    help="full trace durations (slow)")
    ap.add_argument("--record", action="store_true",
                    help="append wall times to the trajectory log")
    ap.add_argument("--compare", action="store_true",
                    help=f"fail when a benchmark regresses "
                         f">{REGRESSION_PCT:.0f}%% vs its last "
                         f"recorded wall time")
    ap.add_argument("--trajectory", type=str, default=TRAJECTORY_PATH,
                    help="trajectory JSONL path")
    args = ap.parse_args(argv)
    names = (
        [n.strip() for n in args.only.split(",") if n.strip()]
        if args.only
        else list(MODULES)
    )
    baselines = load_baselines(args.trajectory) if args.compare else {}
    sha = _git_sha() if args.record else None
    failures = []
    regressions = []
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        print(f"### bench {name} ###", flush=True)
        try:
            mod.run(quick=not args.full)
            wall = time.time() - t0
            print(f"### bench {name} done in {wall:.1f}s ###",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            continue
        if args.compare and name in baselines:
            base = baselines[name]
            limit = base * (1.0 + REGRESSION_PCT / 100.0)
            if wall > limit:
                regressions.append((name, base, wall))
                print(f"### bench {name} REGRESSED: {wall:.1f}s vs "
                      f"baseline {base:.1f}s "
                      f"(>{REGRESSION_PCT:.0f}%) ###", flush=True)
        if args.record:
            record_entry(args.trajectory, name, wall, sha)
    if failures:
        print("FAILURES:", failures)
        return 1
    if regressions:
        print("REGRESSIONS:",
              [(n, f"{b:.1f}s -> {w:.1f}s") for n, b, w in regressions])
        return 1
    print("all benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Fig. 6 analogue: request processing vs network RTT + live JAX engine
microbenchmark (CPU, small model) — proves the data plane runs for real."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import emit_csv, save
from repro.cluster.catalog import default_catalog, region_rtt_ms
from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serving.latency import LatencyModel


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    cat = default_catalog()

    # ---- Fig. 6a/6b: latency model decomposition vs RTT ----------------
    cfg = get_config("command-r-35b")
    lm = LatencyModel.for_model(cfg, cat.instance_type("g5.48xlarge"))
    prefill = lm.prefill_s(20)
    decode = 44 * lm.decode_s_per_token()
    rows.append(
        {
            "metric": "vicuna13b_class_breakdown",
            "prefill_s_20tok": round(prefill, 4),
            "decode_s_44tok": round(decode, 4),
            "rtt_us_eu_s": round(
                region_rtt_ms("us-east-1", "eu-central-1") / 1e3, 4
            ),
            "processing_over_rtt": round(
                (prefill + decode)
                / (region_rtt_ms("us-east-1", "eu-central-1") / 1e3), 1
            ),
        }
    )

    # ---- live engine on CPU (reduced model): tokens/s -------------------
    cfg_s = get_smoke_config("llama3.2-1b")
    model = build_model(cfg_s)
    params = model.init(jax.random.PRNGKey(0))
    B, S0, steps = 4, 16, 24 if not quick else 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                              cfg_s.vocab_size)
    cache = model.init_cache(B, S0 + steps + 4)

    prefill_fn = jax.jit(
        lambda p, t, c: model.prefill(p, t, c)
    )
    decode_fn = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c)
    )
    lg, cache = prefill_fn(params, toks, cache)
    jax.block_until_ready(lg)
    t0 = time.time()
    lg, cache2 = prefill_fn(params, toks, model.init_cache(B, S0 + steps + 4))
    jax.block_until_ready(lg)
    prefill_t = time.time() - t0

    # warm up the decode compile
    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    lg_w, cache = decode_fn(params, tok, cache)
    jax.block_until_ready(lg_w)
    t0 = time.time()
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(steps):
        lg, cache = decode_fn(params, tok, cache)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    decode_t = time.time() - t0
    rows.append(
        {
            "metric": "live_engine_cpu_smoke",
            "prefill_us_per_call": round(prefill_t * 1e6, 1),
            "decode_us_per_token": round(decode_t / steps / B * 1e6, 1),
            "decode_tokens_per_s": round(steps * B / decode_t, 1),
        }
    )
    save("engine_bench", rows)
    emit_csv("engine_bench", rows)
    return rows


if __name__ == "__main__":
    run()

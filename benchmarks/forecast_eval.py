"""Forecaster evaluation: backtests vs. persistence + risk-aware serving.

Two questions, one driver:

1. **Do the learned forecasters beat persistence?**  Every registered
   forecaster is backtested over the named traces (Brier score of the
   availability forecast, averaged over the 5/15/30-minute horizons).
   Per-(trace, forecaster) backtest artifacts land under
   ``artifacts/forecast/``; the comparison table (with explicit
   ``beats_persistence`` verdicts) lands in
   ``artifacts/bench/forecast_eval.json``.

2. **Does risk-aware placement pay off end to end?**  ``risk_spothedge``
   vs. vanilla ``spothedge`` on every named trace through the
   scenario-matrix engine (availability/cost focus: ``workload: none``,
   constant N_Tar — the Fig. 14 setting).  The ScenarioReport lands in
   ``artifacts/bench/scenario_forecast_risk.json``.

    PYTHONPATH=src python -m benchmarks.forecast_eval [--quick]
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

from benchmarks.common import emit_csv, run_suite, save
from repro.cluster.traces import load_trace
from repro.experiments import Scenario, ScenarioSuite
from repro.forecast import registered_forecasters, run_backtest
from repro.service import spec_from_dict

TRACES = ("aws-1", "aws-2", "aws-3", "gcp-1")

#: serving-comparison horizon per trace (capped by trace length)
MAX_DAYS = 7.0


def eval_forecasters(
    traces: Sequence[str] = TRACES,
    *,
    max_steps: Optional[int] = None,
    art_dir: str = "artifacts/forecast",
) -> List[Dict]:
    """Backtest every registered forecaster on every trace."""
    rows: List[Dict] = []
    baseline: Dict[str, float] = {}
    for tname in traces:
        trace = load_trace(tname)
        for fc in registered_forecasters():
            report = run_backtest(trace, fc, max_steps=max_steps)
            report.save(art_dir)
            row: Dict = {
                "trace": tname,
                "forecaster": fc,
                "mean_brier_avail": round(report.mean_brier_avail, 6),
            }
            for h in report.horizons:
                m = int(h.seconds / 60)
                row[f"brier_{m}min"] = round(h.brier_avail, 6)
                row[f"hit_{m}min"] = round(h.hit_rate, 6)
            rows.append(row)
            if fc == "persistence":
                baseline[tname] = report.mean_brier_avail
    for row in rows:
        if row["forecaster"] != "persistence":
            row["beats_persistence"] = bool(
                row["mean_brier_avail"] < baseline[row["trace"]]
            )
    return rows


def build_serving_suite(
    traces: Sequence[str] = TRACES, *, quick: bool = False
) -> ScenarioSuite:
    """risk_spothedge vs. spothedge per trace, availability/cost focus.

    Programmatic scenarios (not a ``sweep:`` grid) because each trace
    gets its own horizon: the full trace up to ``MAX_DAYS``.
    """
    scenarios: List[Scenario] = []
    for tname in traces:
        trace = load_trace(tname)
        hours = min(trace.duration_s / 3600.0, MAX_DAYS * 24.0)
        if quick:
            hours = min(hours, 24.0)
        for policy in ("spothedge", "risk_spothedge"):
            spec = spec_from_dict({
                "name": f"forecast-risk-{policy}-{tname}",
                "model": "llama3.2-1b",
                "trace": tname,
                "resources": {"instance_type": "p3.2xlarge"},
                "replica_policy": {"name": policy},
                "autoscaler": {"kind": "constant", "target": 4},
                "workload": {"kind": "none"},
                "forecast": {"name": "markov"},
                "sim": {
                    "duration_hours": hours,
                    "control_interval_s": 30.0,
                    "drain_s": 0.0,
                    "seed": 0,
                },
            })
            scenarios.append(
                Scenario(labels={"policy": policy, "trace": tname},
                         spec=spec)
            )
    return ScenarioSuite(scenarios, name="forecast_risk")


def run(quick: bool = False) -> List[Dict]:
    max_steps = 2000 if quick else None
    rows = eval_forecasters(TRACES, max_steps=max_steps)
    save("forecast_eval", rows)
    emit_csv("forecast_eval", rows)

    report = run_suite(build_serving_suite(TRACES, quick=quick),
                       workers=None)
    headline: List[Dict] = []
    for tname in TRACES:
        base = next(c for c in report.cells
                    if c.labels == {"policy": "spothedge", "trace": tname})
        risk = next(
            c for c in report.cells
            if c.labels == {"policy": "risk_spothedge", "trace": tname}
        )
        headline.append({
            "trace": tname,
            "avail_spothedge": round(base.availability, 6),
            "avail_risk": round(risk.availability, 6),
            "cost_spothedge": round(base.cost_vs_ondemand, 6),
            "cost_risk": round(risk.cost_vs_ondemand, 6),
            "preempt_spothedge": base.n_preemptions,
            "preempt_risk": risk.n_preemptions,
            "risk_wins": bool(
                risk.availability >= base.availability
                and risk.cost_vs_ondemand <= base.cost_vs_ondemand
            ),
        })
    emit_csv("forecast_risk_headline", headline)
    save("forecast_risk_headline", headline)
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="truncated backtests + 24h serving runs (CI)")
    args = ap.parse_args(argv)
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())

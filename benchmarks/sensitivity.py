"""Fig. 14c/d: sensitivity to N_Extra (overprovision) and cold start d —
custom scenario axes (not the standard sweep grid), still executed through
the scenario-matrix engine with one shared request tape."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks.common import emit_csv, run_suite, save, variant
from repro.experiments import Scenario, ScenarioSuite
from repro.service import ReplicaPolicySpec, spec_from_dict


def build_suite(hours: float) -> ScenarioSuite:
    base = spec_from_dict({
        "name": "sensitivity",
        "model": "llama3.2-1b",
        "trace": "gcp-1",
        "resources": {"instance_type": "a2-ultragpu-4g"},
        "replica_policy": {"name": "spothedge", "overprovision": 2},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "poisson", "rate_per_s": 1.0, "seed": 3},
        "sim": {"duration_hours": hours, "timeout_s": 60.0,
                "concurrency": 2, "control_interval_s": 15.0},
    })

    def cell(sweep: str, n_extra: int, cold: float) -> Scenario:
        return Scenario(
            labels={"sweep": sweep, "n_extra": n_extra,
                    "cold_start_s": cold},
            spec=variant(
                base,
                replica_policy=ReplicaPolicySpec(
                    name="spothedge", overprovision=n_extra
                ),
                sim=dataclasses.replace(base.sim, cold_start_s=cold),
            ),
            tape_key=("sensitivity", hours),
        )

    scenarios = [
        # Fig. 14c: sweep N_Extra at the default cold start
        *(cell("n_extra", n, 183.0) for n in (0, 1, 2, 3, 4)),
        # Fig. 14d: sweep cold start at the default N_Extra
        *(cell("cold_start", 2, c) for c in (60.0, 183.0, 300.0, 600.0)),
    ]
    return ScenarioSuite(scenarios, name="sensitivity")


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    report = run_suite(build_suite(hours))
    rows: List[Dict] = [
        {
            "sweep": c.labels["sweep"],
            "n_extra": c.labels["n_extra"],
            "cold_start_s": c.labels["cold_start_s"],
            "p50_s": round(c.p50_s, 3),
            "p99_s": round(c.p99_s, 3),
            "failure_rate": round(c.failure_rate, 4),
            "cost_vs_od": round(c.cost_vs_ondemand, 4),
            "availability": round(c.availability, 4),
        }
        for c in report.cells
    ]
    save("sensitivity", rows)
    emit_csv("sensitivity", rows)
    return rows


if __name__ == "__main__":
    run()

"""Fig. 14c/d: sensitivity to N_Extra (overprovision) and cold start d."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, save
from repro.cluster.simulator import SimConfig
from repro.cluster.traces import TraceLibrary
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.serving.sim import ServingSimulator
from repro.workloads import make_workload


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    tr = TraceLibrary().get("gcp-1")
    cfg = get_config("llama3.2-1b")
    wl = make_workload("poisson", rate_per_s=1.0, seed=3)
    reqs = wl.generate(hours * 3600 - 600)
    rows: List[Dict] = []

    def one(n_extra: int, cold: float) -> Dict:
        sim = ServingSimulator(
            tr, make_policy("spothedge", num_overprovision=n_extra), reqs,
            cfg, itype="a2-ultragpu-4g",
            autoscaler=ConstantTarget(4), timeout_s=60.0, concurrency=2,
            workload_name="poisson",
            sim_config=SimConfig(itype="a2-ultragpu-4g",
                                 cold_start_s=cold,
                                 control_interval_s=15.0),
        )
        res = sim.run(hours * 3600)
        return {
            "p50_s": round(res.pct(50), 3),
            "p99_s": round(res.pct(99), 3),
            "failure_rate": round(res.failure_rate, 4),
            "cost_vs_od": round(res.cost_vs_ondemand, 4),
            "availability": round(res.availability, 4),
        }

    # Fig. 14c: sweep N_Extra at the default cold start
    for n_extra in (0, 1, 2, 3, 4):
        rows.append({"sweep": "n_extra", "n_extra": n_extra,
                     "cold_start_s": 183.0, **one(n_extra, 183.0)})
    # Fig. 14d: sweep cold start at the default N_Extra
    for cold in (60.0, 183.0, 300.0, 600.0):
        rows.append({"sweep": "cold_start", "n_extra": 2,
                     "cold_start_s": cold, **one(2, cold)})
    save("sensitivity", rows)
    emit_csv("sensitivity", rows)
    return rows


if __name__ == "__main__":
    run()

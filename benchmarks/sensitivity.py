"""Fig. 14c/d: sensitivity to N_Extra (overprovision) and cold start d —
each point a ServiceSpec variant sharing one request tape."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks.common import emit_csv, run_service, save, tape, variant
from repro.service import ReplicaPolicySpec, spec_from_dict


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    base = spec_from_dict({
        "name": "sensitivity",
        "model": "llama3.2-1b",
        "trace": "gcp-1",
        "resources": {"instance_type": "a2-ultragpu-4g"},
        "replica_policy": {"name": "spothedge", "overprovision": 2},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "poisson", "rate_per_s": 1.0, "seed": 3},
        "sim": {"duration_hours": hours, "timeout_s": 60.0,
                "concurrency": 2, "control_interval_s": 15.0},
    })
    reqs = tape(base)
    rows: List[Dict] = []

    def one(n_extra: int, cold: float) -> Dict:
        spec = variant(
            base,
            replica_policy=ReplicaPolicySpec(
                name="spothedge", overprovision=n_extra
            ),
            sim=dataclasses.replace(base.sim, cold_start_s=cold),
        )
        res = run_service(spec, requests=reqs, duration_s=hours * 3600)
        return {
            "p50_s": round(res.pct(50), 3),
            "p99_s": round(res.pct(99), 3),
            "failure_rate": round(res.failure_rate, 4),
            "cost_vs_od": round(res.cost_vs_ondemand, 4),
            "availability": round(res.availability, 4),
        }

    # Fig. 14c: sweep N_Extra at the default cold start
    for n_extra in (0, 1, 2, 3, 4):
        rows.append({"sweep": "n_extra", "n_extra": n_extra,
                     "cold_start_s": 183.0, **one(n_extra, 183.0)})
    # Fig. 14d: sweep cold start at the default N_Extra
    for cold in (60.0, 183.0, 300.0, 600.0):
        rows.append({"sweep": "cold_start", "n_extra": 2,
                     "cold_start_s": cold, **one(2, cold)})
    save("sensitivity", rows)
    emit_csv("sensitivity", rows)
    return rows


if __name__ == "__main__":
    run()

"""Benchmark harness: one module per paper table/figure + roofline.

Run everything:   PYTHONPATH=src python -m benchmarks.run
Run one:          PYTHONPATH=src python -m benchmarks.run --only availability
"""

"""Shared helpers for the benchmark modules."""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

ART = os.path.join("artifacts", "bench")


def save(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def emit_csv(name: str, rows: List[Dict[str, Any]]) -> None:
    """Print ``name,key=value,...`` lines (the bench_output.txt format)."""
    for r in rows:
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{kv}")


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0

"""Shared helpers for the benchmark modules.

Every serving benchmark constructs its runs through the declarative
service API: build a base :class:`ServiceSpec` dict, derive variants with
``variant()``, and execute through the scenario-matrix engine
(:func:`run_suite` / :class:`repro.experiments.ScenarioSuite`) so all
drivers share one execution path.  Scenarios of one sweep replay
identical request tapes via ``Scenario.tape_key``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.cluster.traces import SpotTrace
from repro.experiments import ScenarioReport, ScenarioSuite
from repro.serving.sim import ServingResult
from repro.service import Service, ServiceSpec
from repro.workloads import Request

ART = os.path.join("artifacts", "bench")


def variant(spec: ServiceSpec, **field_replacements: Any) -> ServiceSpec:
    """A spec with top-level fields swapped (frozen dataclass replace)."""
    return dataclasses.replace(spec, **field_replacements)


def run_service(
    spec: ServiceSpec | Dict[str, Any],
    *,
    trace: Optional[SpotTrace] = None,
    requests: Optional[Sequence[Request]] = None,
    duration_s: Optional[float] = None,
) -> ServingResult:
    """Compile + run one declared service; returns its ServingResult."""
    return Service(spec, trace=trace, requests=requests).run(duration_s)


def run_suite(
    suite: ScenarioSuite,
    *,
    engine: Optional[str] = None,
    workers: "int | str | None" = "auto",
    save: bool = True,
) -> ScenarioReport:
    """Run a scenario suite with the bench defaults and save its report."""
    return suite.run(
        engine=engine,
        workers=workers,
        save_to=ART if save else None,
    )


def save(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def emit_csv(name: str, rows: List[Dict[str, Any]]) -> None:
    """Print ``name,key=value,...`` lines (the bench_output.txt format)."""
    for r in rows:
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{kv}")


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0

"""Shared helpers for the benchmark modules.

Every serving benchmark constructs its runs through the declarative
service API: build a base :class:`ServiceSpec` dict, derive variants with
``variant()``, and execute with ``run_service()``.  ``tape()`` generates
one request tape to replay across all variants of a sweep (so systems see
identical arrivals).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.cluster.traces import SpotTrace
from repro.serving.sim import ServingResult
from repro.service import Service, ServiceSpec, build_requests
from repro.workloads import Request

ART = os.path.join("artifacts", "bench")


def variant(spec: ServiceSpec, **field_replacements: Any) -> ServiceSpec:
    """A spec with top-level fields swapped (frozen dataclass replace)."""
    return dataclasses.replace(spec, **field_replacements)


def tape(spec: ServiceSpec) -> List[Request]:
    """The spec's request tape, for replay across a sweep's variants."""
    return build_requests(spec)


def run_service(
    spec: ServiceSpec | Dict[str, Any],
    *,
    trace: Optional[SpotTrace] = None,
    requests: Optional[Sequence[Request]] = None,
    duration_s: Optional[float] = None,
) -> ServingResult:
    """Compile + run one declared service; returns its ServingResult."""
    return Service(spec, trace=trace, requests=requests).run(duration_s)


def save(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def emit_csv(name: str, rows: List[Dict[str, Any]]) -> None:
    """Print ``name,key=value,...`` lines (the bench_output.txt format)."""
    for r in rows:
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{kv}")


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0

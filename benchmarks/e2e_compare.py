"""Fig. 9/10/13: end-to-end serving comparison with injected preemptions.

SkyServe(SpotHedge) vs ASG(static mixture) vs AWSSpot(single-region even
spread) vs MArk-like, serving the command-r-35b (Llama-2-70B-class) replica
on g5.48xlarge under the Arena workload.  Each system is a ServiceSpec
variant of one base spec; the whole matrix is a
:class:`repro.experiments.ScenarioSuite` (single-region baselines get an
``any_of`` resource filter pinning them to us-west-2, like the paper's
setup).  Two scenario groups: Spot Available vs Spot Volatile (trace
windows selected by spot obtainability, like §5.1).  All systems of a
group replay one request tape.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, run_suite, save, variant
from repro.cluster.traces import SpotTrace, TraceLibrary
from repro.experiments import Scenario, ScenarioSuite
from repro.service import (
    PlacementFilter,
    ReplicaPolicySpec,
    ResourceSpec,
    spec_from_dict,
)

SYSTEMS = {
    # system -> (policy spec, single_region_only)
    "skyserve": (ReplicaPolicySpec(name="spothedge"), False),
    "asg": (
        ReplicaPolicySpec(name="static_mixture", args={"od_fraction": 0.1}),
        True,
    ),
    "aws_spot": (ReplicaPolicySpec(name="aws_spot"), True),
    "mark": (ReplicaPolicySpec(name="mark_like"), True),
    "ondemand": (ReplicaPolicySpec(name="ondemand_only"), False),
}

WEST_ONLY = ResourceSpec(
    instance_type="g5.48xlarge",
    any_of=(PlacementFilter(region="us-west-2"),),
)


def _base_spec(hours: float):
    return spec_from_dict({
        "name": "e2e-compare",
        "model": "command-r-35b",
        "trace": "aws-3",                # 9 zones, 3+ regions
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {
            "kind": "load",
            "target": 5,
            "qps_per_replica": 0.6,
            "min_replicas": 2,
            "max_replicas": 14,
            "upscale_delay_s": 30.0,
            "downscale_delay_s": 600.0,
        },
        "workload": {"kind": "arena", "rate_per_s": 2.5, "seed": 7},
        "sim": {
            "duration_hours": hours,
            "control_interval_s": 15.0,
            "timeout_s": 100.0,
            "concurrency": 4,
        },
    })


def _window(tr: SpotTrace, hours: float, volatile: bool) -> SpotTrace:
    """Pick a window whose us-west-2 obtainability matches the paper's
    scenario groups: Spot Available 91-100 %, Spot Volatile 45-46 %."""
    steps = int(hours * 3600 / tr.dt)
    target = 0.45 if volatile else 0.97
    west = [i for i, z in enumerate(tr.zones) if z.startswith("us-west-2")]
    best, best_score = 0, None
    stride = max(1, steps // 8)
    for s0 in range(0, tr.steps - steps, stride):
        win = tr.cap[s0 : s0 + steps][:, west]
        obt = (win > 0).any(axis=1).mean()
        score = -abs(obt - target)
        if best_score is None or score > best_score:
            best, best_score = s0, score
    return SpotTrace(
        zones=tr.zones, cap=tr.cap[best : best + steps], dt=tr.dt,
        name=f"{tr.name}-{'volatile' if volatile else 'available'}",
    )


def build_suite(hours: float) -> ScenarioSuite:
    """The policy × trace-window matrix as one ScenarioSuite."""
    base = _base_spec(hours)
    tr_full = TraceLibrary().get(base.trace)
    scenarios: List[Scenario] = []
    for volatile in (False, True):
        tr = _window(tr_full, hours, volatile)
        group = "volatile" if volatile else "available"
        for system, (policy, single_region) in SYSTEMS.items():
            spec = variant(
                base,
                name=f"e2e-{system}",
                replica_policy=policy,
                resources=WEST_ONLY if single_region else base.resources,
            )
            scenarios.append(
                Scenario(
                    labels={"scenario": group, "system": system},
                    spec=spec,
                    trace=tr,
                    # identical arrivals for every system of a group
                    tape_key=("e2e", hours),
                )
            )
    return ScenarioSuite(scenarios, name="e2e_compare")


def run(hours: float = 8.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 4.0
    report = run_suite(build_suite(hours))
    rows: List[Dict] = [
        {
            "scenario": c.labels["scenario"],
            "system": c.labels["system"],
            "p50_s": round(c.p50_s, 2),
            "p90_s": round(c.p90_s, 2),
            "p99_s": round(c.p99_s, 2),
            "failure_rate": round(c.failure_rate, 4),
            "cost_vs_od": round(c.cost_vs_ondemand, 4),
            "availability": round(c.availability, 4),
            "n_requests": c.n_requests,
        }
        for c in report.cells
    ]
    save("e2e_compare", rows)
    emit_csv("e2e_compare", rows)

    # headline: latency improvement factors vs each baseline (paper quotes
    # 2.3x/2.1x/2.1x average)
    headline: List[Dict] = []
    for scenario in ("available", "volatile"):
        sky = next(r for r in rows if r["system"] == "skyserve"
                   and r["scenario"] == scenario)
        for r in rows:
            if r["scenario"] != scenario or r["system"] in ("skyserve",
                                                            "ondemand"):
                continue
            headline.append(
                {
                    "scenario": scenario,
                    "vs": r["system"],
                    "p50_x": round(r["p50_s"] / max(sky["p50_s"], 1e-9), 2),
                    "p90_x": round(r["p90_s"] / max(sky["p90_s"], 1e-9), 2),
                    "p99_x": round(r["p99_s"] / max(sky["p99_s"], 1e-9), 2),
                }
            )
    emit_csv("e2e_headline", headline)
    save("e2e_headline", headline)
    return rows


if __name__ == "__main__":
    run()

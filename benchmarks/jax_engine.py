"""JAX scenario-engine throughput: one vmapped XLA program per matrix.

Runs a wide policy×seed matrix (many same-shape cells — the workload the
jit/vmap engine exists for) through ``ScenarioSuite.run(engine="jax")``
and records wall-clock to ``artifacts/bench/jax_engine.json``:

* ``jax`` — phase A (per-cell control-plane replay) + phase B (all
  request-model data planes batched into one ``lax.scan`` program per
  shape group), cold (includes XLA compile) and warm;
* ``vector`` — the per-cell NumPy engine on the same matrix, serial;
* ``legacy`` — the per-request object simulator on a sampled sub-matrix
  (it is far too slow to run the full grid), reported per-cell.

The headline ``speedup_vs_recorded_legacy_x`` compares matrix throughput
(cells/s) against the legacy serial throughput recorded in
``artifacts/bench/engine_speedup.json``.  Cell composition differs
between the two artifacts (this matrix: 1 h cells at 1 req/s; the
recorded baseline: 8 h e2e cells at ~2.5 req/s), so the row also carries
``hours`` / ``requests_per_cell`` for this matrix, the baseline's
``recorded_*`` fields, and same-matrix ratios (``same_matrix_*``)
measured on identical cells — read the ratio you care about.

Jax and vector metrics are asserted identical cell-for-cell (the
differential guarantee of tests/test_jax_engine.py, re-checked here
end-to-end), so the timing comparison is apples-to-apples.
"""

from __future__ import annotations

import json
import math
import os
from typing import Dict, List

from benchmarks.common import ART, emit_csv, save
from repro.experiments import ScenarioSuite

# recorded headline of benchmarks/engine_speedup.py (the pre-jax
# artifact this benchmark is measured against); used as a fallback when
# artifacts/bench/engine_speedup.json is absent
_RECORDED_LEGACY = {"legacy_serial_s": 28.73, "n_cells": 10, "hours": 8.0}


def _spec(n_seeds: int, hours: float) -> Dict:
    return {
        "name": "jaxeng",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "replica_policy": {"name": "spothedge"},
        "autoscaler": {"kind": "constant", "target": 3},
        "workload": {"kind": "poisson", "rate_per_s": 1.0, "seed": 0},
        "sim": {
            "duration_hours": hours,
            "timeout_s": 60.0,
            "concurrency": 4,
            "drain_s": 300.0,
        },
        "sweep": {
            "policies": ["spothedge", "even_spread"],
            "seeds": list(range(n_seeds)),
        },
    }


def build_suite(n_seeds: int = 48, hours: float = 1.0) -> ScenarioSuite:
    return ScenarioSuite.from_spec(_spec(n_seeds, hours), name="jax_engine")


def _strip_wall(cells) -> List[Dict]:
    return [
        {k: v for k, v in c.to_dict(round_to=None).items()
         if k != "wall_s"}
        for c in cells
    ]


def _cells_match(a: List[Dict], b: List[Dict]) -> bool:
    """Cell-for-cell equality, floats to 1e-9 relative.

    Counts must match exactly; derived aggregates (mean/percentiles) may
    differ in the last ulp because the engines sum latencies in a
    different order (np.mean is pairwise, hence order-sensitive).
    """
    if len(a) != len(b):
        return False
    for ca, cb in zip(a, b):
        if ca.keys() != cb.keys():
            return False
        for k, va in ca.items():
            vb = cb[k]
            if isinstance(va, float) and isinstance(vb, float):
                if not math.isclose(va, vb, rel_tol=1e-9, abs_tol=1e-12):
                    return False
            elif va != vb:
                return False
    return True


def _recorded_baseline() -> Dict:
    path = os.path.join(ART, "engine_speedup.json")
    if os.path.exists(path):
        with open(path) as f:
            for row in json.load(f):
                if row.get("metric") == "e2e_matrix_wall_clock":
                    return row
    return dict(_RECORDED_LEGACY)


def run(quick: bool = False) -> List[Dict]:
    n_seeds = 4 if quick else 48
    hours = 1.0
    suite = build_suite(n_seeds, hours)
    n_cells = len(suite)

    # first jax run pays tracing + XLA compile; the kernel cache is
    # process-global, so the second run isolates steady-state throughput
    jax_cold = suite.run(engine="jax")
    jax_warm = suite.run(engine="jax")
    vector = suite.run(engine="vector")

    if not _cells_match(_strip_wall(jax_warm.cells),
                        _strip_wall(vector.cells)):
        raise AssertionError(
            "jax engine diverged from the vector engine on the wide "
            "matrix — differential guarantee violated"
        )

    # legacy on a sampled sub-matrix: same spec, first seeds only
    legacy_seeds = min(2, n_seeds)
    legacy = build_suite(legacy_seeds, hours).run(engine="legacy")
    legacy_per_cell = legacy.wall_s / len(legacy.cells)

    base = _recorded_baseline()
    recorded_cells_per_s = base["n_cells"] / base["legacy_serial_s"]
    thpt = n_cells / jax_warm.wall_s

    spec = _spec(n_seeds, hours)
    rate = spec["workload"]["rate_per_s"]
    horizon = hours * 3600.0 - spec["sim"]["drain_s"]

    rows: List[Dict] = [
        {
            "metric": "jax_matrix_throughput",
            "n_cells": n_cells,
            "hours": hours,
            "rate_per_s": rate,
            "requests_per_cell": int(rate * horizon),
            "jax_cold_s": round(jax_cold.wall_s, 2),
            "jax_warm_s": round(jax_warm.wall_s, 2),
            "compile_s": round(jax_cold.wall_s - jax_warm.wall_s, 2),
            "throughput_cells_per_s": round(thpt, 2),
            "recorded_legacy_cells_per_s": round(recorded_cells_per_s, 3),
            "recorded_legacy_hours": base["hours"],
            "speedup_vs_recorded_legacy_x": round(
                thpt / recorded_cells_per_s, 1
            ),
            "vector_serial_s": round(vector.wall_s, 2),
            "same_matrix_vs_vector_x": round(
                vector.wall_s / jax_warm.wall_s, 2
            ),
            "legacy_sampled_cells": len(legacy.cells),
            "legacy_sample_per_cell_s": round(legacy_per_cell, 3),
            "same_matrix_vs_legacy_x": round(
                legacy_per_cell / (jax_warm.wall_s / n_cells), 2
            ),
            "metrics_identical": True,
        }
    ]
    save("jax_engine", rows)
    emit_csv("jax_engine", rows)
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)

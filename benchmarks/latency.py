"""Fig. 15: service latency across traces × workloads × policies — the
scenario grid declared as a ``sweep:`` section and executed through the
scenario-matrix engine (one tape per workload cell, shared across traces
and policies)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, run_suite, save
from repro.experiments import ScenarioSuite

POLICIES = ("even_spread", "round_robin", "spothedge")
WORKLOADS = ("poisson", "arena", "maf")
TRACES = ("aws-1", "aws-2", "gcp-1")


def build_suite(hours: float) -> ScenarioSuite:
    return ScenarioSuite.from_spec({
        "name": "latency",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "poisson", "rate_per_s": 1.2, "seed": 5},
        "sim": {"duration_hours": hours, "timeout_s": 60.0,
                "concurrency": 2},
        "sweep": {
            "policies": list(POLICIES),
            "traces": list(TRACES),
            "workloads": [
                {"kind": w, "rate_per_s": 1.2, "seed": 5} for w in WORKLOADS
            ],
        },
    })


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    report = run_suite(build_suite(hours))
    rows: List[Dict] = [
        {
            "trace": c.labels["trace"],
            "workload": c.labels["workload"],
            "policy": c.labels["policy"],
            "mean_s": round(c.mean_s, 3),
            "p50_s": round(c.p50_s, 3),
            "p99_s": round(c.p99_s, 3),
            "failure_rate": round(c.failure_rate, 4),
        }
        for c in report.cells
    ]
    save("latency", rows)
    emit_csv("latency", rows)
    return rows


if __name__ == "__main__":
    run()

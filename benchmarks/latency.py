"""Fig. 15: service latency across traces × workloads × policies — each
cell one ServiceSpec variant of a single base spec."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from benchmarks.common import emit_csv, run_service, save, tape, variant
from repro.service import ReplicaPolicySpec, spec_from_dict

POLICIES = ("even_spread", "round_robin", "spothedge")
WORKLOADS = ("poisson", "arena", "maf")
TRACES = ("aws-1", "aws-2", "gcp-1")


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    base = spec_from_dict({
        "name": "latency-sweep",
        "model": "llama3.2-1b",
        "trace": "aws-1",
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "poisson", "rate_per_s": 1.2, "seed": 5},
        "sim": {"duration_hours": hours, "timeout_s": 60.0,
                "concurrency": 2},
    })
    rows: List[Dict] = []
    for tname in TRACES:
        for wname in WORKLOADS:
            wl_spec = variant(
                base,
                trace=tname,
                workload=dataclasses.replace(base.workload, kind=wname),
            )
            reqs = tape(wl_spec)    # one tape per (trace, workload) cell
            for pol in POLICIES:
                res = run_service(
                    variant(wl_spec,
                            replica_policy=ReplicaPolicySpec(name=pol)),
                    requests=reqs,
                    duration_s=hours * 3600,
                )
                rows.append(
                    {
                        "trace": tname,
                        "workload": wname,
                        "policy": pol,
                        "mean_s": round(
                            float(res.latencies_s.mean())
                            if len(res.latencies_s) else float("nan"), 3
                        ),
                        "p50_s": round(res.pct(50), 3),
                        "p99_s": round(res.pct(99), 3),
                        "failure_rate": round(res.failure_rate, 4),
                    }
                )
    save("latency", rows)
    emit_csv("latency", rows)
    return rows


if __name__ == "__main__":
    run()

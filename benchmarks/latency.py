"""Fig. 15: service latency across traces × workloads × policies."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, save
from repro.cluster.traces import TraceLibrary
from repro.configs import get_config
from repro.core.autoscaler import ConstantTarget
from repro.core.policy import make_policy
from repro.serving.sim import ServingSimulator
from repro.workloads import make_workload

POLICIES = ("even_spread", "round_robin", "spothedge")
WORKLOADS = ("poisson", "arena", "maf")
TRACES = ("aws-1", "aws-2", "gcp-1")
ITYPES = {"aws-1": "g5.48xlarge", "aws-2": "g5.48xlarge",
          "gcp-1": "g5.48xlarge"}


def run(hours: float = 6.0, quick: bool = False) -> List[Dict]:
    if quick:
        hours = 3.0
    lib = TraceLibrary()
    cfg = get_config("llama3.2-1b")
    rows: List[Dict] = []
    for tname in TRACES:
        tr = lib.get(tname)
        for wname in WORKLOADS:
            wl = make_workload(wname, seed=5, **(
                {"rate_per_s": 1.2} if wname == "poisson"
                else {"base_rate_per_s": 1.2}
            ))
            reqs = wl.generate(hours * 3600 - 600)
            for pol in POLICIES:
                sim = ServingSimulator(
                    tr, make_policy(pol), reqs, cfg,
                    itype=ITYPES[tname],
                    autoscaler=ConstantTarget(4),
                    timeout_s=60.0, workload_name=wname, concurrency=2,
                )
                res = sim.run(hours * 3600)
                rows.append(
                    {
                        "trace": tname,
                        "workload": wname,
                        "policy": pol,
                        "mean_s": round(
                            float(res.latencies_s.mean())
                            if len(res.latencies_s) else float("nan"), 3
                        ),
                        "p50_s": round(res.pct(50), 3),
                        "p99_s": round(res.pct(99), 3),
                        "failure_rate": round(res.failure_rate, 4),
                    }
                )
    save("latency", rows)
    emit_csv("latency", rows)
    return rows


if __name__ == "__main__":
    run()

"""Engine speedup: the e2e_compare policy×trace matrix, legacy vs vector.

Runs the exact same scenario matrix as ``benchmarks/e2e_compare.py``
three ways and records wall-clock to ``artifacts/bench/engine_speedup.json``:

* ``legacy`` — the per-request object simulator (``serving/sim.py``),
  serial: the pre-PR execution path;
* ``vector`` — the NumPy engine (``serving/engine.py``), serial: isolates
  the hot-path speedup;
* ``vector_parallel`` — the NumPy engine with the suite fanning cells out
  over worker processes: the shipped default path for scenario matrices.

The metrics of all three are asserted identical cell-for-cell (the
differential guarantee, end-to-end), so the timing comparison is
apples-to-apples.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, save
from benchmarks.e2e_compare import build_suite


def _strip_wall(cells) -> List[Dict]:
    return [
        {k: v for k, v in c.to_dict(round_to=None).items()
         if k != "wall_s"}
        for c in cells
    ]


def run(hours: float = 8.0, quick: bool = False) -> List[Dict]:
    trials = 1 if quick else 2
    if quick:
        hours = 4.0
    suite = build_suite(hours)

    def best(**kw):
        # min over trials: wall-clock on shared machines is noisy upward
        runs = [suite.run(**kw) for _ in range(trials)]
        return min(runs, key=lambda r: r.wall_s)

    legacy = best(engine="legacy")
    vector = best(engine="vector")
    vector_par = best(engine="vector", workers="auto")

    if _strip_wall(legacy.cells) != _strip_wall(vector.cells):
        raise AssertionError(
            "vector engine diverged from the legacy simulator on the "
            "e2e matrix — differential guarantee violated"
        )
    if _strip_wall(vector.cells) != _strip_wall(vector_par.cells):
        raise AssertionError(
            "parallel suite execution changed metrics — cells must be "
            "independent"
        )

    rows: List[Dict] = [
        {
            "metric": "e2e_matrix_wall_clock",
            "hours": hours,
            "n_cells": len(legacy),
            "legacy_serial_s": round(legacy.wall_s, 2),
            "vector_serial_s": round(vector.wall_s, 2),
            "vector_parallel_s": round(vector_par.wall_s, 2),
            "parallel_workers": vector_par.workers,
            "engine_speedup_x": round(legacy.wall_s / vector.wall_s, 2),
            "matrix_speedup_x": round(
                legacy.wall_s / vector_par.wall_s, 2
            ),
            "metrics_identical": True,
        }
    ]
    rows += [
        {
            "metric": "per_cell_wall_clock",
            "cell": c_leg.cell_id,
            "legacy_s": round(c_leg.wall_s, 3),
            "vector_s": round(c_vec.wall_s, 3),
            "speedup_x": round(c_leg.wall_s / max(c_vec.wall_s, 1e-9), 2),
        }
        for c_leg, c_vec in zip(legacy.cells, vector.cells)
    ]
    save("engine_speedup", rows)
    emit_csv("engine_speedup", rows)
    return rows


if __name__ == "__main__":
    run()

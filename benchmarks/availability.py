"""Fig. 14a: availability per (trace × policy) with simulated preemptions."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit_csv, save
from repro.cluster.simulator import run_policy_on_trace
from repro.cluster.traces import TraceLibrary

POLICIES = ("even_spread", "round_robin", "spothedge", "omniscient")
TRACES = ("aws-1", "aws-2", "aws-3", "gcp-1")
ITYPES = {"aws-1": "p3.2xlarge", "aws-2": "p3.2xlarge",
          "aws-3": "p3.2xlarge", "gcp-1": "a2-ultragpu-4g"}


def run(n_target: int = 4, quick: bool = False) -> List[Dict]:
    lib = TraceLibrary()
    rows: List[Dict] = []
    for tname in TRACES:
        tr = lib.get(tname)
        dur = min(tr.duration_s, 5 * 86_400.0) if quick else None
        for pol in POLICIES:
            res = run_policy_on_trace(
                pol, tr, n_target=n_target, itype=ITYPES[tname],
                control_interval_s=30.0, duration_s=dur,
            )
            rows.append(
                {
                    "trace": tname,
                    "policy": pol,
                    "availability": round(res.availability, 4),
                    "preemptions": res.n_preemptions,
                    "launch_failures": res.n_launch_failures,
                }
            )
    save("availability", rows)
    emit_csv("availability", rows)
    return rows


if __name__ == "__main__":
    run()

"""Grace-period KV migration vs kill-and-re-prefill (ISSUE 6 headline).

Replays the token-engine benchmark tapes (command-r-35b on g5.48xlarge,
arena workload) with migration OFF (every warned preemption kills the
batch — the status quo) and ON (the ``repro.migration`` planner drains
near-finished sequences in the grace window and ships resident KV to
surviving replicas, int8-compressed) for the ``spothedge`` and
``risk_spothedge`` policies over named spot traces.  The request tape,
trace and policy decisions are identical across the pair — migration
only changes what happens inside the preemption warning window — so the
TTFT-p99 / goodput deltas isolate the value of not re-prefilling.

The arrival rate defaults to 4 req/s: at chat-scale occupancy a
preempted replica holds several in-flight sequences with KV worth
shipping, which is the regime SpotServe (arxiv 2311.15566) targets.
``drain_threshold_s`` is set to 2 s so only sequences within two
seconds of completion finish in place; everything else must migrate or
die, exercising the transfer cost model rather than the drain
short-circuit.

    PYTHONPATH=src python benchmarks/migration.py
    PYTHONPATH=src python benchmarks/migration.py \
        --traces aws-1 --hours 0.75 --stem migration_smoke

Writes ``artifacts/bench/<stem>.json`` (schema 1): the scenario cells
plus a per-trace × policy headline with the off/on rows, the
ttft_p99/goodput deltas, and the migration counters.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Optional

from benchmarks.common import ART, emit_csv, run_suite
from repro.experiments import ScenarioSuite
from repro.service import spec_from_dict

SCHEMA_VERSION = 1

POLICIES = ["spothedge", "risk_spothedge"]


def base_spec_dict(traces: List[str], hours: float, rate: float,
                   seed: int) -> Dict[str, Any]:
    return {
        "name": "migration",
        "model": "command-r-35b",
        "trace": traces[0],
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "arena", "rate_per_s": rate, "seed": seed},
        "forecast": {"name": "markov"},
        "serving": {
            "replica_model": "token",
            "slo": {"ttft_s": 10.0, "tpot_s": 0.2},
        },
        "migration": {
            "enabled": False,
            "compression": "int8",
            "drain_threshold_s": 2.0,
        },
        "sim": {
            "duration_hours": hours,
            "control_interval_s": 15.0,
            "timeout_s": 100.0,
            "concurrency": 4,
            "drain_s": 300.0,
        },
        "sweep": {
            "policies": POLICIES,
            "traces": traces,
            "migration": [False, True],
        },
    }


def _cell_row(c) -> Dict[str, Any]:
    row = {
        "ttft_p50_s": c.ttft_p50_s, "ttft_p99_s": c.ttft_p99_s,
        "p99_s": c.p99_s,
        "goodput_rps": c.goodput_rps,
        "slo_attainment": c.slo_attainment,
        "failure_rate": round(c.failure_rate, 6),
        "cost_vs_ondemand": round(c.cost_vs_ondemand, 6),
        "total_cost": round(c.total_cost, 6),
        "n_preemptions": c.n_preemptions,
        "n_retried_requests": c.n_retried_requests,
        "lost_kv_tokens": c.lost_kv_tokens,
    }
    if c.n_migrated_seqs or c.n_drained_seqs:
        row.update(
            n_drained_seqs=c.n_drained_seqs,
            n_migrated_seqs=c.n_migrated_seqs,
            migrated_kv_tokens=c.migrated_kv_tokens,
            saved_prefill_tokens=c.saved_prefill_tokens,
        )
    return row


def headline(report, traces: List[str]) -> Dict[str, Any]:
    """Per trace × policy: migration off vs on at the same cost."""
    out: Dict[str, Any] = {}
    for tr in traces:
        out[tr] = {}
        for pol in POLICIES:
            cells = {
                c.labels["migration"]: c
                for c in report.select(policy=pol, trace=tr)
            }
            if set(cells) != {"off", "on"}:
                continue
            off, on = cells["off"], cells["on"]
            out[tr][pol] = {
                "off": _cell_row(off),
                "on": _cell_row(on),
                # negative deltas = migration wins
                "ttft_p99_delta_s": round(
                    on.ttft_p99_s - off.ttft_p99_s, 6
                ),
                "goodput_delta_rps": round(
                    on.goodput_rps - off.goodput_rps, 6
                ),
                "slo_attainment_delta": round(
                    on.slo_attainment - off.slo_attainment, 6
                ),
                # same trace, same policy decisions -> same bill; a
                # nonzero delta would mean migration leaked into the
                # control plane
                "cost_delta": round(on.total_cost - off.total_cost, 6),
                "migrated_tokens": on.migrated_kv_tokens,
                "saved_prefill_tokens": on.saved_prefill_tokens,
                "n_drained_seqs": on.n_drained_seqs,
                "n_migrated_seqs": on.n_migrated_seqs,
            }
    return out


def run(quick: bool = False) -> int:
    """benchmarks.run entry: quick = one trace over a short window."""
    argv = ["--traces", "aws-1", "--hours", "0.75"] if quick else []
    return main(argv)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--traces", nargs="+", default=["aws-1", "aws-3"])
    ap.add_argument("--hours", type=float, default=2.0)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--workers", default="auto")
    ap.add_argument("--stem", default="migration",
                    help="artifact name under artifacts/bench/")
    args = ap.parse_args(argv)

    spec = spec_from_dict(
        base_spec_dict(args.traces, args.hours, args.rate, args.seed)
    )
    suite = ScenarioSuite.from_spec(spec, name=args.stem)
    print(f"[migration] {len(suite)} cells "
          f"({', '.join(args.traces)} × policies × migration off/on)")
    report = run_suite(suite, workers=args.workers, save=False)
    print(report.summary())

    doc = {
        "schema": SCHEMA_VERSION,
        "suite": args.stem,
        "model": spec.model,
        "instance_type": spec.resources.instance_type,
        "workload": spec.workload.to_dict(),
        "slo": spec.serving.slo.to_dict(),
        "migration": spec.migration.to_dict(),
        "hours": args.hours,
        "wall_s": round(report.wall_s, 3),
        "cells": [c.to_dict() for c in report.cells],
        "headline": headline(report, args.traces),
    }
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{args.stem}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    print(f"[migration] artifact: {path}")

    emit_csv("migration", [
        {k: c.to_dict().get(k) for k in
         ("policy", "trace", "migration", "ttft_p99_s", "goodput_rps",
          "slo_attainment", "n_migrated_seqs", "migrated_kv_tokens",
          "saved_prefill_tokens", "cost_vs_ondemand")}
        for c in report.cells
    ])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

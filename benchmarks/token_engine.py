"""Token-level vs request-level serving comparison (ISSUE 5 headline).

Runs the ``spothedge`` vs ``ondemand_only`` policies on named spot traces
through *both* replica models — the historical request-level M/G/c model
and the new token-level continuous-batching engine — replaying one request
tape per trace (``sweep.replica_models`` axis).  The token-level cells add
TTFT/TPOT percentiles and goodput-vs-SLO, which is where batch dynamics
and preemption KV loss actually show up: the request-level model prices a
replica's capacity with a frozen service time and a ``1 + 0.15·running``
factor, the token engine prices it with the HBM roofline (weights
amortized across the batch, KV reads per sequence) and re-prefills
KV-destroyed requests after preemptions.

    PYTHONPATH=src python benchmarks/token_engine.py
    PYTHONPATH=src python benchmarks/token_engine.py \
        --traces aws-1 --hours 0.75 --stem token_engine_smoke

Writes ``artifacts/bench/<stem>.json`` (schema 1): the scenario cells
plus a per-trace headline comparing request vs token P50/P99/TTFT/goodput
for each policy.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Optional

from benchmarks.common import ART, emit_csv, run_suite
from repro.experiments import ScenarioSuite
from repro.service import spec_from_dict

SCHEMA_VERSION = 1


def base_spec_dict(traces: List[str], hours: float, rate: float,
                   seed: int) -> Dict[str, Any]:
    return {
        "name": "token-engine",
        # a 35B-class model: decode steps are ~20 ms, so batching and
        # KV pressure are visible at chat-scale request rates
        "model": "command-r-35b",
        "trace": traces[0],
        "resources": {"instance_type": "g5.48xlarge"},
        "autoscaler": {"kind": "constant", "target": 4},
        "workload": {"kind": "arena", "rate_per_s": rate, "seed": seed},
        "serving": {
            "slo": {"ttft_s": 10.0, "tpot_s": 0.2},
        },
        "sim": {
            "duration_hours": hours,
            "control_interval_s": 15.0,
            "timeout_s": 100.0,
            "concurrency": 4,
            "drain_s": 300.0,
        },
        "sweep": {
            "policies": ["spothedge", "ondemand_only"],
            "traces": traces,
            "replica_models": ["request", "token"],
        },
    }


def _cell_row(c) -> Dict[str, Any]:
    row = {
        "p50_s": c.p50_s, "p99_s": c.p99_s,
        "failure_rate": round(c.failure_rate, 6),
        "cost_vs_ondemand": round(c.cost_vs_ondemand, 6),
        "n_preemptions": c.n_preemptions,
    }
    if c.goodput_rps is not None:
        row.update(
            ttft_p50_s=c.ttft_p50_s, ttft_p99_s=c.ttft_p99_s,
            tpot_p50_s=c.tpot_p50_s, goodput_rps=c.goodput_rps,
            slo_attainment=c.slo_attainment,
        )
    return row


def headline(report, traces: List[str]) -> Dict[str, Any]:
    """Per trace × policy: request-level vs token-level side by side."""
    out: Dict[str, Any] = {}
    for tr in traces:
        out[tr] = {}
        for pol in ("spothedge", "ondemand_only"):
            cells = {
                c.labels["replica_model"]: c
                for c in report.select(policy=pol, trace=tr)
            }
            if set(cells) != {"request", "token"}:
                continue
            req, tok = cells["request"], cells["token"]
            out[tr][pol] = {
                "request": _cell_row(req),
                "token": _cell_row(tok),
                # the modeling delta the ISSUE asks to surface
                "p99_shift_s": round(tok.p99_s - req.p99_s, 6),
            }
        both = out[tr]
        if set(both) == {"spothedge", "ondemand_only"}:
            sh, od = both["spothedge"]["token"], \
                both["ondemand_only"]["token"]
            out[tr]["token_separation"] = {
                "ttft_p99_delta_s": round(
                    sh["ttft_p99_s"] - od["ttft_p99_s"], 6
                ),
                "p99_delta_s": round(sh["p99_s"] - od["p99_s"], 6),
                "goodput_delta_rps": round(
                    sh["goodput_rps"] - od["goodput_rps"], 6
                ),
                "slo_attainment_delta": round(
                    sh["slo_attainment"] - od["slo_attainment"], 6
                ),
                "spothedge_cost_vs_od": sh["cost_vs_ondemand"],
            }
    return out


def run(quick: bool = False) -> int:
    """benchmarks.run entry: quick = one trace over a short window."""
    argv = ["--traces", "aws-1", "--hours", "0.75"] if quick else []
    return main(argv)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--traces", nargs="+", default=["aws-1", "aws-3"])
    ap.add_argument("--hours", type=float, default=2.0)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--workers", default="auto")
    ap.add_argument("--stem", default="token_engine",
                    help="artifact name under artifacts/bench/")
    args = ap.parse_args(argv)

    spec = spec_from_dict(
        base_spec_dict(args.traces, args.hours, args.rate, args.seed)
    )
    suite = ScenarioSuite.from_spec(spec, name=args.stem)
    print(f"[token_engine] {len(suite)} cells "
          f"({', '.join(args.traces)} × policies × replica models)")
    report = run_suite(suite, workers=args.workers, save=False)
    print(report.summary())

    doc = {
        "schema": SCHEMA_VERSION,
        "suite": args.stem,
        "model": spec.model,
        "instance_type": spec.resources.instance_type,
        "workload": spec.workload.to_dict(),
        "slo": spec.serving.slo.to_dict(),
        "hours": args.hours,
        "wall_s": round(report.wall_s, 3),
        "cells": [c.to_dict() for c in report.cells],
        "headline": headline(report, args.traces),
    }
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{args.stem}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    print(f"[token_engine] artifact: {path}")

    emit_csv("token_engine", [
        {k: c.to_dict().get(k) for k in
         ("policy", "trace", "replica_model", "p50_s", "p99_s",
          "ttft_p50_s", "ttft_p99_s", "goodput_rps", "slo_attainment",
          "cost_vs_ondemand")}
        for c in report.cells
    ])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
